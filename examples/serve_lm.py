"""Batched serving example: greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m --requests 12
    PYTHONPATH=src python examples/serve_lm.py --engine static --deadline-s 30
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--engine", choices=("static", "continuous"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--deadline-s", type=float, default=None)
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--engine", args.engine,
            "--requests", str(args.requests), "--max-new", str(args.max_new)]
    if args.deadline_s is not None:
        argv += ["--deadline-s", str(args.deadline_s)]
    done = S.main(argv)
    # Only requests that ran to completion owe the full token budget;
    # timed_out / failed requests finalize early with partial output.
    assert all(len(r.out) == args.max_new
               for r in done if r.status == "ok")
    ok = sum(r.status == "ok" for r in done)
    print(f"[serve_lm] {len(done)} requests served ({ok} ok)")


if __name__ == "__main__":
    main()
