"""Batched serving example: wave-batched greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m
    PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m --requests 12
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    done = S.main(["--arch", args.arch, "--requests", str(args.requests),
                   "--max-new", str(args.max_new)])
    assert all(len(r.out) == args.max_new for r in done)
    print(f"[serve_lm] {len(done)} requests served")


if __name__ == "__main__":
    main()
