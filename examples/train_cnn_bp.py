"""Train a strided CNN classifier with a selectable conv-backprop engine
policy -- the paper's training scenario, end-to-end.

    PYTHONPATH=src python examples/train_cnn_bp.py --policy bp_phase
    PYTHONPATH=src python examples/train_cnn_bp.py \
        --policy fwd=lax,dgrad=pallas,wgrad=bp_phase --steps 200

Policies: a uniform engine name (lax | traditional | bp_im2col | bp_phase |
pallas), "auto" (per-pass shape-dependent selection), or an explicit
per-pass string fwd=...,dgrad=...,wgrad=...  All reach the same losses
(engines are exact); wall-clock differences on CPU echo the paper's
reorganization-elimination claim (traditional pays for the zero-space
copies; see benchmarks/bench_kernels.py for controlled numbers).

The model goes through ``repro.models.layers`` conv layers, so ``jax.grad``
dispatches every conv backward through the policy's per-pass engines via
the ``custom_vjp`` -- the same wiring the full training stack
(``repro.train.train_step``) uses.  The second conv is depthwise
(``groups=C``) to exercise the grouped datapath.
"""

import argparse
import sys
import time
import warnings

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


def make_model(policy):
    def forward(params, x):
        h = L.conv2d_apply(params["c1"], x, stride=2, padding=1,
                           policy=policy)
        h = jax.nn.relu(h)                                # 16x16 -> 8x8
        h = L.conv2d_apply(params["dw"], h, stride=1, padding=1,
                           policy=policy, groups=16)      # depthwise 8x8
        h = jax.nn.relu(h)
        h = L.conv2d_apply(params["c2"], h, stride=2, padding=1,
                           policy=policy)
        h = jax.nn.relu(h)                                # 8x8 -> 4x4
        h = h.mean((2, 3))                                # GAP
        return h @ params["head"]

    def loss_fn(params, x, y):
        logits = forward(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    return forward, loss_fn


def init_params(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    rng = np.random.RandomState(seed)
    return {
        "c1": L.init_conv2d(ks[0], 3, 16, 3, jnp.float32),
        "dw": L.init_conv2d(ks[1], 16, 16, 3, jnp.float32, groups=16),
        "c2": L.init_conv2d(ks[2], 16, 32, 3, jnp.float32),
        "head": jnp.asarray(rng.randn(32, 4) * 0.1, jnp.float32),
    }


def synthetic_task(rng, n, classes=4):
    """Learnable synthetic vision task: class = dominant quadrant pattern."""
    x = rng.randn(n, 3, 16, 16).astype(np.float32)
    y = rng.randint(0, classes, n)
    for i in range(n):
        q = y[i]
        r0, c0 = (q // 2) * 8, (q % 2) * 8
        x[i, :, r0:r0 + 8, c0:c0 + 8] += 2.0
    return jnp.asarray(x), jnp.asarray(y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None,
                    help="engine policy: a uniform engine name, 'auto' "
                         "(per-pass shape-dependent selection), or a "
                         "per-pass string fwd=...,dgrad=...,wgrad=... "
                         "(default bp_phase)")
    ap.add_argument("--mode", default=None,
                    choices=["lax", "traditional", "bp_im2col", "bp_phase",
                             "pallas"],
                    help="DEPRECATED compatibility alias: maps to a "
                         "uniform --policy and warns; use --policy")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--acc-floor", type=float, default=0.9)
    ap.add_argument("--autotune", default=None,
                    choices=["off", "measure", "cached"],
                    help="measured autotuning of the Pallas tile plans "
                         "(repro.config.autotune)")
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persistent plan-cache directory "
                         "(repro.config.plan_cache_dir)")
    ap.add_argument("--fault-spec", default=None,
                    help="arm the fault injector (repro.config.fault_spec), "
                         "e.g. 'pallas.*:raise@step3' -- see "
                         "examples/train_chaos.py for the full chaos drill")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write a Perfetto trace_event "
                         "JSON (repro.obs) to PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable telemetry and stream per-step metrics "
                         "JSONL to PATH")
    args = ap.parse_args()
    if args.autotune is not None or args.plan_cache_dir is not None \
            or args.fault_spec is not None or args.trace is not None \
            or args.metrics is not None:
        from repro.core.config import config
        config.update(**{k: v for k, v in
                         (("autotune", args.autotune),
                          ("plan_cache_dir", args.plan_cache_dir),
                          ("fault_spec", args.fault_spec),
                          ("telemetry", bool(args.trace or args.metrics)
                           or None),
                          ("trace_path", args.trace),
                          ("metrics_path", args.metrics))
                         if v is not None})
    if args.mode is not None:
        warnings.warn("--mode is deprecated; use --policy",
                      DeprecationWarning)
        if args.policy is not None:
            raise SystemExit("pass either --policy or the deprecated "
                             "--mode, not both")
    policy = args.policy or args.mode or "bp_phase"

    from repro import obs

    rng = np.random.RandomState(0)
    _, loss_fn = make_model(policy)
    params = init_params()
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.perf_counter()
    for step in range(args.steps):
        ts = time.perf_counter()
        if args.fault_spec:
            from repro.ft import inject
            inject.set_step(step)
        x, y = synthetic_task(rng, args.batch)
        with obs.trace.span("train:step", step=step):
            loss, g = grad_fn(params, x, y)
            params = jax.tree.map(lambda p, gg: p - args.lr * gg, params, g)
        obs.metrics.train_step(step, {"loss": float(loss)},
                               step_s=time.perf_counter() - ts)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"[{policy}] step={step:4d} loss={float(loss):.4f}")
    dt = time.perf_counter() - t0
    xe, ye = synthetic_task(np.random.RandomState(1), 256)
    fwd, _ = make_model(policy)
    acc = float((jnp.argmax(fwd(params, xe), -1) == ye).mean())
    print(f"[{policy}] done in {dt:.1f}s  eval_acc={acc:.3f}")
    assert acc > args.acc_floor, "training failed to learn the synthetic task"
    if obs.enabled():
        rep = obs.finalize()
        print(f"[{policy}] obs: {rep['events_total']} events "
              f"{rep['events_by_kind']} trace={rep['trace_file']} "
              f"metrics={rep['metrics']['lines']} lines")
        # The CI obs lane's divergence gate: every legacy counter must
        # agree with its bus-backed view.
        assert rep["consistent"], (
            "telemetry divergence: " + "; ".join(rep["divergences"]))
        if args.trace:
            assert rep["trace"]["spans_by_prefix"].get("conv", 0) > 0, \
                "telemetry on but no conv dispatch spans were traced"
        if args.metrics:
            assert rep["metrics"]["lines"] >= args.steps, rep["metrics"]


if __name__ == "__main__":
    main()
