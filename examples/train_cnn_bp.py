"""Train a strided CNN classifier with a selectable conv-backprop engine --
the paper's training scenario, end-to-end.

    PYTHONPATH=src python examples/train_cnn_bp.py --mode bp_phase --steps 200

Modes: lax | traditional | bp_im2col | bp_phase | pallas.  All reach the
same losses (engines are exact); wall-clock differences on CPU echo the
paper's reorganization-elimination claim (traditional pays for the
zero-space copies; see benchmarks/bench_kernels.py for controlled numbers).
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import conv2d


def make_model(mode):
    def forward(params, x):
        h = conv2d(x, params["w1"], 2, (1, 1), mode)      # 16x16 -> 8x8
        h = jax.nn.relu(h)
        h = conv2d(h, params["w2"], 2, (1, 1), mode)      # 8x8 -> 4x4
        h = jax.nn.relu(h)
        h = h.mean((2, 3))                                # GAP
        return h @ params["head"]

    def loss_fn(params, x, y):
        logits = forward(params, x)
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], 1).mean()

    return forward, loss_fn


def synthetic_task(rng, n, classes=4):
    """Learnable synthetic vision task: class = dominant quadrant pattern."""
    x = rng.randn(n, 3, 16, 16).astype(np.float32)
    y = rng.randint(0, classes, n)
    for i in range(n):
        q = y[i]
        r0, c0 = (q // 2) * 8, (q % 2) * 8
        x[i, :, r0:r0 + 8, c0:c0 + 8] += 2.0
    return jnp.asarray(x), jnp.asarray(y)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="bp_phase",
                    choices=["lax", "traditional", "bp_im2col", "bp_phase",
                             "pallas"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    args = ap.parse_args()

    rng = np.random.RandomState(0)
    _, loss_fn = make_model(args.mode)
    params = {
        "w1": jnp.asarray(rng.randn(16, 3, 3, 3) * 0.2, jnp.float32),
        "w2": jnp.asarray(rng.randn(32, 16, 3, 3) * 0.1, jnp.float32),
        "head": jnp.asarray(rng.randn(32, 4) * 0.1, jnp.float32),
    }
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    t0 = time.perf_counter()
    for step in range(args.steps):
        x, y = synthetic_task(rng, args.batch)
        loss, g = grad_fn(params, x, y)
        params = jax.tree.map(lambda p, gg: p - args.lr * gg, params, g)
        if step % 20 == 0 or step == args.steps - 1:
            print(f"[{args.mode}] step={step:4d} loss={float(loss):.4f}")
    dt = time.perf_counter() - t0
    xe, ye = synthetic_task(np.random.RandomState(1), 256)
    fwd, _ = make_model(args.mode)
    acc = float((jnp.argmax(fwd(params, xe), -1) == ye).mean())
    print(f"[{args.mode}] done in {dt:.1f}s  eval_acc={acc:.3f}")
    assert acc > 0.9, "training failed to learn the synthetic task"


if __name__ == "__main__":
    main()
