"""Train a conv -> conv_transpose autoencoder with a selectable conv-backprop
engine policy -- the transposed-conv-as-forward workload (decoders, GAN
generators, upsampling heads), end-to-end through ``make_train_step``.

    PYTHONPATH=src python examples/train_autoencoder_bp.py --policy auto
    PYTHONPATH=src python examples/train_autoencoder_bp.py \
        --policy fwd=pallas,dgrad=bp_phase,wgrad=bp_im2col --steps 200

Policies: a uniform engine name (lax | traditional | bp_im2col | bp_phase |
pallas), "auto" (per-pass shape-dependent selection), or an explicit
per-pass string fwd=...,dgrad=...,wgrad=...  The decoder's stride-2
``conv2d_transpose`` layers run zero-insertion-free on every
transpose-native engine (the stride IS the zero-insertion the paper's
transposed mode skips); "traditional" physically materializes the
zero-spaced input -- the paper's baseline -- and reaches the same losses.

Unlike ``train_cnn_bp.py``'s hand-rolled SGD loop, this example drives the
REAL training stack: ``repro.train.make_train_step`` with the
``loss=autoencoder_loss`` plugin, AdamW, LR schedule, and
``conv_policy=`` threading the per-pass engines into every conv and
conv_transpose of the model.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.models import model as M
from repro.optim import adamw
from repro.train import make_train_step


def synthetic_images(rng, n, c=3, size=16):
    """Learnable reconstruction task: smooth low-frequency blobs (a few
    random Fourier modes per image), not raw noise."""
    yy, xx = np.meshgrid(np.arange(size), np.arange(size), indexing="ij")
    out = np.zeros((n, c, size, size), np.float32)
    for i in range(n):
        for ch in range(c):
            fy, fx = rng.randint(1, 4, 2)
            py, px = rng.rand(2) * 2 * np.pi
            amp = rng.rand() + 0.5
            out[i, ch] = amp * np.sin(2 * np.pi * fy * yy / size + py) \
                * np.cos(2 * np.pi * fx * xx / size + px)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="auto",
                    help="engine policy: a uniform engine name, 'auto' "
                         "(per-pass shape-dependent selection), or a "
                         "per-pass string fwd=...,dgrad=...,wgrad=...")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--size", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--mse-floor", type=float, default=0.05,
                    help="final reconstruction MSE must fall below this")
    args = ap.parse_args()

    cfg = M.AutoencoderConfig(c_in=3, widths=(16, 32), k=3,
                              conv_policy=args.policy)
    params = M.init_autoencoder(jax.random.PRNGKey(0), cfg)
    opt_state = adamw.init_state(params)
    step_fn = jax.jit(make_train_step(
        cfg, adamw.AdamWConfig(peak_lr=args.lr, weight_decay=0.0),
        total_steps=args.steps, warmup=max(1, args.steps // 10),
        loss=M.autoencoder_loss, conv_policy=args.policy))

    rng = np.random.RandomState(0)
    t0 = time.perf_counter()
    mse = float("nan")
    for step in range(args.steps):
        batch = {"image": synthetic_images(rng, args.batch, cfg.c_in,
                                           args.size)}
        params, opt_state, metrics = step_fn(params, opt_state, batch, step)
        mse = float(metrics["mse"])
        if step % 20 == 0 or step == args.steps - 1:
            print(f"[{args.policy}] step={step:4d} mse={mse:.5f}")
    dt = time.perf_counter() - t0
    print(f"[{args.policy}] done in {dt:.1f}s  final_mse={mse:.5f}")
    assert mse < args.mse_floor, (
        f"autoencoder failed to learn: mse {mse:.5f} >= {args.mse_floor}")


if __name__ == "__main__":
    main()
