"""End-to-end LM training driver on the framework's full substrate stack:
deterministic pipeline -> jitted train step (AdamW + schedule + accum) ->
checkpoints -> fault-tolerance bookkeeping.

Default is CPU-smoke scale; ``--full`` selects the real smollm-360m config
(the '~100M-class model for a few hundred steps' driver -- run it on real
accelerators; on this CPU container it would take hours).

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --arch minicpm-2b --steps 60
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="published config instead of the reduced one")
    ap.add_argument("--ckpt-dir", default=None,
                    help="set to resume; default is a fresh temp dir")
    args = ap.parse_args()
    if args.ckpt_dir is None:
        args.ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_ckpt_")

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir,
            "--ckpt-every", "50"]
    if not args.full:
        argv.append("--smoke")
    losses = T.main(argv)
    assert losses[-1] < losses[0], "loss did not decrease"
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
