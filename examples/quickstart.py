"""Quickstart: the paper's BP-im2col in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks through:
  1. a strided conv layer's backprop zero-space problem (sparsity numbers),
  2. Algorithm 1/2 implicit address mapping == explicit zero-spaced lowering,
  3. gradients from the implicit engines == jax.grad ground truth,
  4. the traffic/bandwidth savings the paper reports.
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bpim2col as bp
from repro.core import im2col_ref as ref
from repro.core import phase_decomp as ph
from repro.core.im2col_ref import ConvDims

# A conv layer from the paper's Table II (scaled-down channels for CPU).
d = ConvDims(B=2, C=8, H_i=28, W_i=28, N=16, K_h=3, K_w=3, S=2, P_h=1, P_w=1)
print(f"layer: H={d.H_i} C={d.C} N={d.N} K={d.K_h} S={d.S} P={d.P_h}"
      f" -> H_o={d.H_o}")

# 1. the zero-space problem
print(f"\nzero-spaced loss map: {d.H_o}x{d.W_o} -> {d.H_o3}x{d.W_o3} "
      f"({d.zero_space_sparsity_loss():.1%} zeros)")
print(f"lowered matrix B sparsity (loss calc):  "
      f"{bp.lowered_sparsity_loss(d):.1%}  <- paper: 75%..93.91%")
print(f"zero-inserted dY sparsity (grad calc):  "
      f"{bp.lowered_sparsity_grad(d):.1%}  <- paper: 74.8%..93.6%")

# 2. Algorithm 1: implicit gather == explicit zero-spaced lowering
rng = np.random.RandomState(0)
dy = jnp.asarray(rng.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
implicit = bp.gather_lowered_B_loss(dy, d)
explicit = ref.im2col(ref.zero_insert_pad(dy, d), d.K_h, d.K_w, 1).T
np.testing.assert_allclose(implicit, explicit, rtol=1e-6)
print("\nAlgorithm 1 implicit lowering == explicit zero-spaced lowering  OK")

# 3. gradients match jax.grad exactly
x = jnp.asarray(rng.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
w = jnp.asarray(rng.randn(d.N, d.C, d.K_h, d.K_w), jnp.float32)
di_ref, dw_ref = ref.conv_grads_lax(x, w, dy, d)
np.testing.assert_allclose(bp.input_grad_implicit(dy, w, d), di_ref,
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(ph.weight_grad_phase(x, dy, d), dw_ref,
                           rtol=2e-3, atol=2e-3)
print("BP-im2col gradients == jax.grad                                  OK")

# 4. traffic savings
t = ref.reorg_traffic_elems_loss(d)
o = bp.bp_traffic_elems_loss(d)
print(f"\ntraditional: reorg {t['reorg_read']+t['reorg_write']:,} elems, "
      f"off-chip stream {t['offchip_stream']:,}, "
      f"buffer stream {t['buffer_stream']:,}")
print(f"BP-im2col:   reorg 0 elems, off-chip stream {o['offchip_stream']:,},"
      f" buffer stream {o['buffer_stream']:,}")
print(f"buffer-bandwidth reduction: "
      f"{1 - o['buffer_stream']/t['buffer_stream']:.1%} "
      f"(paper: >= 70.6%)")
print(f"extra backprop storage eliminated: {t['extra_storage']:,} elems "
      f"(paper: >= 74.78% reduction)")
