"""Chaos drill: train the CNN while the fault injector kills the Pallas
engine mid-run and poisons one step's gradients -- and assert the stack
degrades EXACTLY as designed instead of merely surviving.

    PYTHONPATH=src python examples/train_chaos.py

Timeline (fault spec ``pallas.*:raise@step3;grad.values:nan@step5``, with
``QUARANTINE_PROBE_AFTER`` lowered to 2 so the whole arc fits a short run):

    step 3   every Pallas launch raises ``InjectedFault``; the dispatch
             layer re-runs each pass on the fallback chain
             (``pass:pallas->bp_phase`` events) and quarantines pallas for
             each failing (pass, geometry)
    steps 4-5  quarantined: pallas is skipped outright
             (``pass:pallas:quarantined``)
    step 5   the gradient VALUES are NaN-poisoned; the loop's numerical
             guard drops the update (params untouched)
    step 6   recovery probe: pallas is retried, succeeds, quarantine is
             lifted (``pass:pallas:probe`` + ``pass:pallas:recovered``)
    then     disarm and run two more steps -- zero faults may fire
             (the injector is config-gated, not baked into the trace)

The run must complete with a finite, decreasing loss; every expected event
count is asserted exactly (computed from ``resolve_engine``, so a planner
that routes a layer off pallas does not break the drill).  This is the CI
``chaos`` lane's workload.
"""

import argparse
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from train_cnn_bp import init_params, make_model, synthetic_task

from repro import obs
from repro.core import conv
from repro.core.config import config
from repro.core.convspec import ConvSpec
from repro.ft import inject
from repro.ft.failures import GuardState

FAULT_SPEC = "pallas.*:raise@step3;grad.values:nan@step5"
PASSES = ("forward", "input_grad", "weight_grad")


def expected_pallas_passes(batch):
    """How many (pass, layer) pairs resolve to pallas for the CNN's three
    conv layers -- computed through the real resolver so the drill's
    assertions track the planner, not a hardcoded guess."""
    layers = [
        ((batch, 3, 16, 16), (16, 3, 3, 3), ConvSpec.make(stride=2,
                                                          padding=1)),
        ((batch, 16, 8, 8), (16, 1, 3, 3), ConvSpec.make(stride=1, padding=1,
                                                         groups=16)),
        ((batch, 16, 8, 8), (32, 16, 3, 3), ConvSpec.make(stride=2,
                                                          padding=1)),
    ]
    n = {p: 0 for p in PASSES}
    for xs, ws, spec in layers:
        d = conv.spec_dims(xs, ws, spec)
        for p in PASSES:
            if conv.resolve_engine("pallas", p, d)[0] == "pallas":
                n[p] += 1
    return n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=14)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write a Perfetto trace_event "
                         "JSON; the drill then also asserts the degradation "
                         "arc is on the obs bus and the conv spans carry "
                         "skip_ratio/bytes_moved annotations")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable telemetry and stream per-step metrics "
                         "JSONL to PATH")
    args = ap.parse_args()
    assert args.steps >= 8, "the fault timeline needs at least 8 steps"

    conv.QUARANTINE_PROBE_AFTER = 2   # arc: fail@3, skip@4-5, probe@6
    config.update(fault_spec=FAULT_SPEC, fault_seed=0,
                  **{k: v for k, v in
                     (("telemetry", bool(args.trace or args.metrics) or None),
                      ("trace_path", args.trace),
                      ("metrics_path", args.metrics))
                     if v is not None})
    # One reset covering EVERY introspection surface (dispatch events,
    # policy decisions, quarantine, fired faults, the obs bus/trace).
    obs.reset_all()

    n_pallas = expected_pallas_passes(args.batch)
    n_total = sum(n_pallas.values())
    assert n_total > 0, "no layer resolves to pallas; the drill is vacuous"
    print(f"[chaos] armed: {FAULT_SPEC!r}; pallas passes per step: "
          f"{n_pallas}")

    rng = np.random.RandomState(0)
    _, loss_fn = make_model("pallas")
    params = init_params()
    # EAGER on purpose: dispatch happens at trace time, so a jitted step
    # would fault once at compile and never again -- eager re-dispatches
    # every step, which is what makes the quarantine/probe arc observable.
    grad_fn = jax.value_and_grad(loss_fn)
    gs = GuardState(clip_after=2, rollback_after=4)
    losses = []
    for step in range(args.steps):
        inject.set_step(step)
        x, y = synthetic_task(rng, args.batch)
        loss, g = grad_fn(params, x, y)
        g = inject.fault_point("grad.values", value=g)
        gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(v))
                                   for v in jax.tree.leaves(g))))
        bad = not (np.isfinite(float(loss)) and np.isfinite(gnorm))
        action = gs.observe(bad)
        if bad:
            print(f"[chaos] step={step} non-finite gradients dropped "
                  f"(action={action})")
        else:
            params = jax.tree.map(lambda p, gg: p - args.lr * gg, params, g)
            losses.append(float(loss))
        obs.metrics.train_step(step, {"loss": float(loss),
                                      "grad_norm": gnorm,
                                      "guard_bad": float(bad)})
        if step % 2 == 0 or step == args.steps - 1:
            print(f"[chaos] step={step:3d} loss={float(loss):.4f}")

    ev = conv.dispatch_events()
    fired = inject.fired_events()

    # --- the degradation arc, exactly -------------------------------------
    degrade = {k: v for k, v in ev.items() if "->" in k}
    assert sum(degrade.values()) == n_total, \
        f"expected {n_total} runtime failure edges, got {degrade}"
    for p in PASSES:
        if n_pallas[p] == 0:
            continue
        q = ev.get(f"{p}:pallas:quarantined", 0)
        assert q == 2 * n_pallas[p], \
            f"{p}: expected {2 * n_pallas[p]} quarantined skips, got {q}"
        assert ev.get(f"{p}:pallas:probe", 0) == n_pallas[p], ev
        assert ev.get(f"{p}:pallas:recovered", 0) == n_pallas[p], ev
    assert not conv.quarantined_engines(), conv.quarantined_engines()
    raises = [f for f in fired if f["action"] == "raise"]
    nans = [f for f in fired if f["action"] == "nan"]
    assert len(raises) == n_total, (len(raises), n_total)
    assert len(nans) == 1 and nans[0]["site"] == "grad.values", nans
    assert gs.total_bad == 1 and gs.rollbacks == 0, vars(gs)
    rf = conv.runtime_failures()
    assert len(rf) == n_total and \
        all(f["exception"] == "InjectedFault" and f["survivor"] for f in rf)

    # --- the training outcome ---------------------------------------------
    assert all(np.isfinite(l) for l in losses), "non-finite loss leaked"
    half = len(losses) // 2
    assert np.mean(losses[half:]) < np.mean(losses[:half]), \
        "training made no progress through the faults"
    assert all(np.isfinite(np.asarray(v)).all()
               for v in jax.tree.leaves(params)), "non-finite params"

    # --- zero-leak when disarmed ------------------------------------------
    config.update(fault_spec=None)
    inject.reset_events()
    for step in range(2):
        x, y = synthetic_task(rng, args.batch)
        loss, g = grad_fn(params, x, y)
        params = jax.tree.map(lambda p, gg: p - args.lr * gg, params, g)
    assert inject.fired_events() == [], inject.fired_events()
    assert np.isfinite(float(loss))

    # --- the same arc must be on the obs bus ------------------------------
    if obs.enabled():
        rep = obs.finalize()
        # Every legacy counter agrees with its bus-backed view -- including
        # the degrade -> quarantined -> probe -> recovered sequence.
        assert rep["consistent"], (
            "telemetry divergence: " + "; ".join(rep["divergences"]))
        bus = obs.events.counters("dispatch")
        assert bus == conv.dispatch_events(), (bus, conv.dispatch_events())
        for p in PASSES:
            if n_pallas[p] == 0:
                continue
            for arc in (f"{p}:pallas->bp_phase", f"{p}:pallas:quarantined",
                        f"{p}:pallas:probe", f"{p}:pallas:recovered"):
                assert bus.get(arc, 0) > 0, (arc, bus)
        if args.trace:
            trace_doc = json.load(open(args.trace))
            conv_spans = [e for e in trace_doc["traceEvents"]
                          if e["ph"] == "B" and e["name"].startswith("conv:")]
            assert conv_spans, "no conv dispatch spans in the trace"
            for span in conv_spans:
                assert "skip_ratio" in span["args"] and \
                    "bytes_moved" in span["args"], span
        if args.metrics:
            lines = [json.loads(ln) for ln in open(args.metrics)]
            assert len(lines) >= args.steps and \
                all(ln["kind"] == "train_step" for ln in lines), len(lines)
        print(f"[chaos] obs ok: {rep['events_total']} bus events, "
              f"{rep['trace']['events']} trace events, "
              f"{rep['metrics']['lines']} metrics lines")

    print(f"[chaos] ok: {n_total} pallas passes degraded and recovered, "
          f"1 NaN step dropped, final loss {losses[-1]:.4f}, "
          f"zero faults when disarmed")


if __name__ == "__main__":
    main()
