from repro.ckpt.checkpoint import (latest_steps, reset_skipped_checkpoints,
                                   restore, save, skipped_checkpoints, wait)
