from repro.ckpt.checkpoint import save, restore, latest_steps
