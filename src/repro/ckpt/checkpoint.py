"""Checkpoint / restore with step-atomic manifests and elastic resume.

Layout per step:
    <dir>/step_000123/
        manifest.json      {step, tree structure, shapes, dtypes, hashes}
        arr_00000.npy ...  one file per leaf (host-gathered)
        COMMIT             written last; a checkpoint without COMMIT is
                           ignored by restore (atomicity under mid-write
                           failures)

Elastic resume: arrays are stored unsharded (host-gathered), so a restart may
re-shard onto ANY mesh shape -- restore takes an optional NamedSharding tree
and uses jax.device_put per leaf.  Content hashes (sha256 of raw bytes)
detect silent corruption.  ``keep`` rotates old checkpoints.

Async save: ``save(..., blocking=False)`` snapshots to host in the caller
thread (cheap device->host copy) and writes files on a background thread, so
the train loop overlaps checkpoint I/O with compute.  A background write
that FAILS is never silent: the exception is captured and re-raised from the
next ``save()`` or from :func:`wait` (call ``wait()`` before reading
``latest_steps`` at shutdown -- it joins the in-flight write).

Restore is degradation-aware: a candidate checkpoint that cannot be loaded
(truncated array file, manifest hash mismatch, torn write without COMMIT)
is SKIPPED with the reason recorded (:func:`skipped_checkpoints`) and the
next-newest committed step is tried, so one bad checkpoint costs ``keep``
steps of progress, not the run.  Only when NO candidate is loadable -- or
an explicitly requested ``step=`` is bad -- does restore raise.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.ft.inject import fault_point
from repro.obs import events as obs_events
from repro.obs import trace as obs_trace

_STEP_DIR = re.compile(r"step_(\d+)")


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, prefix + (str(i),))
    else:
        yield prefix, tree


def _set_path(tree, path, val):
    for p in path[:-1]:
        tree = tree.setdefault(p, {})
    tree[path[-1]] = val


# ---------------------------------------------------------------------------
# Async writer with exception capture
# ---------------------------------------------------------------------------

class _AsyncWriter:
    """At most one background checkpoint write in flight; its exception
    (if any) is held until the next :meth:`launch` or :meth:`wait`."""

    def __init__(self):
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None

    def _join_locked(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _reraise_locked(self):
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def launch(self, fn) -> None:
        """Wait for the previous write (re-raising its failure), then run
        ``fn`` on a fresh background thread."""
        with self._lock:
            self._join_locked()
            self._reraise_locked()

            def _run():
                try:
                    fn()
                except BaseException as e:   # held, re-raised on next call
                    self._exc = e

            self._thread = threading.Thread(target=_run, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write and re-raise its failure (if any)."""
        with self._lock:
            self._join_locked()
            self._reraise_locked()


_WRITER = _AsyncWriter()


def wait() -> None:
    """Block until any async ``save(..., blocking=False)`` has finished,
    re-raising the background exception if the write failed.  Call before
    reading ``latest_steps`` at shutdown / before a rollback-restore."""
    _WRITER.wait()


# ---------------------------------------------------------------------------
# Save
# ---------------------------------------------------------------------------

def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         blocking: bool = True) -> str:
    """Write a step-atomic checkpoint; returns its directory.

    Non-blocking saves hand the file I/O to a background thread; a failure
    there is re-raised from the NEXT ``save()`` (or :func:`wait`), so a
    dead disk cannot silently eat every checkpoint of a run.
    """
    _WRITER.wait()                    # surface any failed previous write
    leaves = [(".".join(path), np.asarray(leaf))
              for path, leaf in _leaf_paths(tree)]

    def _write_impl():
        fault_point("ckpt.write")
        obs_events.emit("ckpt", "write", step=step, blocking=blocking)
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = d + ".tmp"
        try:
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "leaves": []}
            for i, (name, arr) in enumerate(leaves):
                fn = f"arr_{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest["leaves"].append({
                    "name": name, "file": fn, "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
                })
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
        except BaseException:
            # Never leave a half-written tmp dir behind: the *.tmp suffix
            # already excludes it from latest_steps, but a retry of the
            # same step must start clean.
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        _rotate(ckpt_dir, keep)

    def _write():
        # The span runs on the writer thread for async saves, so the trace
        # shows checkpoint I/O overlapping the training steps on its own
        # tid lane.
        with obs_trace.span("ckpt:write", step=step, leaves=len(leaves),
                            blocking=blocking):
            _write_impl()

    if blocking:
        _write()
    else:
        _WRITER.launch(_write)
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


# ---------------------------------------------------------------------------
# Discovery and skip accounting
# ---------------------------------------------------------------------------

#: restore/discovery decisions to skip a checkpoint, with reasons (bounded).
_SKIPPED: list[dict] = []
_MAX_SKIPPED = 64


def _record_skip(what: str, reason: str) -> None:
    if len(_SKIPPED) < _MAX_SKIPPED:
        _SKIPPED.append({"checkpoint": what, "reason": reason})


def skipped_checkpoints() -> list[dict]:
    """Checkpoints that discovery or restore refused to use, and why
    (torn write without COMMIT, truncated array, hash mismatch, ...)."""
    return list(_SKIPPED)


def reset_skipped_checkpoints() -> None:
    _SKIPPED.clear()


def latest_steps(ckpt_dir: str) -> list[int]:
    """Committed checkpoint steps, ascending.  Torn writes (a ``step_*``
    directory without COMMIT) are skipped and recorded; ``*.tmp`` staging
    dirs and foreign names are ignored."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_DIR.fullmatch(name)
        if not m:
            continue
        if not os.path.exists(os.path.join(ckpt_dir, name, "COMMIT")):
            _record_skip(name, "no COMMIT marker (torn write)")
            continue
        out.append(int(m.group(1)))
    return sorted(out)


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------

def _load_one(ckpt_dir: str, step: int, flat_shard: dict, verify: bool):
    """Load one committed checkpoint or raise (OSError/ValueError/...)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    tree: dict = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != leaf["sha256"]:
                raise IOError(
                    f"checkpoint corruption in {leaf['name']} at step {step}")
        if leaf["name"] in flat_shard:
            arr = jax.device_put(arr, flat_shard[leaf["name"]])
        _set_path(tree, tuple(leaf["name"].split(".")), arr)
    return tree


def restore(ckpt_dir: str, step: Optional[int] = None, *,
            shardings: Any = None, verify: bool = True):
    """Restore the newest LOADABLE committed checkpoint (or the given step).

    shardings: optional pytree of NamedSharding matching the saved tree --
    enables elastic resume onto a different mesh than the one that saved.
    Returns (step, tree) or (None, None) when no checkpoint exists.

    Without an explicit ``step=``, candidates are tried newest-first: a
    checkpoint that fails to load (truncated ``.npy``, manifest hash
    mismatch, unreadable manifest) is skipped with the reason recorded in
    :func:`skipped_checkpoints` and the next-newest is tried.  Only when
    every committed candidate fails does restore raise, with each failure
    (including any corruption) named in the message.  An explicit ``step=``
    never falls back -- a bad requested checkpoint raises immediately.
    """
    fault_point("ckpt.read")
    obs_events.emit("ckpt", "restore", step=step)
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, None
    flat_shard = dict(
        (".".join(p), s) for p, s in _leaf_paths(shardings)) \
        if shardings is not None else {}
    if step is not None:
        try:
            return step, _load_one(ckpt_dir, step, flat_shard, verify)
        except (OSError, ValueError, KeyError, EOFError) as e:
            raise IOError(
                f"requested checkpoint step {step} is not loadable: "
                f"{e}") from e
    errors = []
    for cand in reversed(steps):
        try:
            return cand, _load_one(ckpt_dir, cand, flat_shard, verify)
        except (OSError, ValueError, KeyError, EOFError) as e:
            _record_skip(f"step_{cand:08d}", str(e))
            errors.append(f"step {cand}: {e}")
    raise IOError(
        f"no loadable checkpoint in {ckpt_dir}: " + "; ".join(errors))
