"""Checkpoint / restore with step-atomic manifests and elastic resume.

Layout per step:
    <dir>/step_000123/
        manifest.json      {step, tree structure, shapes, dtypes, hashes}
        arr_00000.npy ...  one file per leaf (host-gathered)
        COMMIT             written last; a checkpoint without COMMIT is
                           ignored by restore (atomicity under mid-write
                           failures)

Elastic resume: arrays are stored unsharded (host-gathered), so a restart may
re-shard onto ANY mesh shape -- restore takes an optional NamedSharding tree
and uses jax.device_put per leaf.  Content hashes (sha256 of raw bytes)
detect silent corruption.  ``keep`` rotates old checkpoints.

Async save: ``save(..., blocking=False)`` snapshots to host in the caller
thread (cheap device->host copy) and writes files on a background thread, so
the train loop overlaps checkpoint I/O with compute.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _leaf_paths(v, prefix + (str(i),))
    else:
        yield prefix, tree


def _set_path(tree, path, val):
    for p in path[:-1]:
        tree = tree.setdefault(p, {})
    tree[path[-1]] = val


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3,
         blocking: bool = True) -> str:
    """Write a step-atomic checkpoint; returns its directory."""
    leaves = [(".".join(path), np.asarray(leaf))
              for path, leaf in _leaf_paths(tree)]

    def _write():
        d = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "leaves": []}
        for i, (name, arr) in enumerate(leaves):
            fn = f"arr_{i:05d}.npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest(),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        _rotate(ckpt_dir, keep)

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def _rotate(ckpt_dir: str, keep: int):
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        d = os.path.join(ckpt_dir, name)
        if name.startswith("step_") and \
                os.path.exists(os.path.join(d, "COMMIT")):
            out.append(int(name[5:]))
    return sorted(out)


def restore(ckpt_dir: str, step: Optional[int] = None, *,
            shardings: Any = None, verify: bool = True):
    """Restore the latest (or given) committed checkpoint.

    shardings: optional pytree of NamedSharding matching the saved tree --
    enables elastic resume onto a different mesh than the one that saved.
    Returns (step, tree) or (None, None) when no checkpoint exists.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        return None, None
    step = step if step is not None else steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shard = dict(
        (".".join(p), s) for p, s in _leaf_paths(shardings)) \
        if shardings is not None else {}
    tree: dict = {}
    for leaf in manifest["leaves"]:
        arr = np.load(os.path.join(d, leaf["file"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()
            if h != leaf["sha256"]:
                raise IOError(
                    f"checkpoint corruption in {leaf['name']} at step {step}")
        if leaf["name"] in flat_shard:
            arr = jax.device_put(arr, flat_shard[leaf["name"]])
        _set_path(tree, tuple(leaf["name"].split(".")), arr)
    return step, tree
