"""The jitted train step: loss -> grads -> AdamW, with optional microbatch
gradient accumulation (collectives overlap at accumulation boundaries) and
optional cross-pod int8 gradient compression with error feedback.

``make_train_step`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings from repro.dist.sharding.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist import conv_parallel
from repro.ft import inject
from repro.models import model as M
from repro.optim import adamw, schedule
from repro.train import losses


def loss_fn(params, batch, cfg: ArchConfig):
    logits, aux = M.forward(params, batch, cfg)
    return losses.train_loss(logits, aux, batch)


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """In-graph numerical guard for the train step.

    A step whose loss or gradient global-norm is non-finite is DROPPED:
    params and optimizer state pass through unchanged (a ``jnp.where``
    select, so the step stays jittable -- no host round-trip).  The
    consecutive-bad streak rides in ``opt_state["guard_streak"]``; once it
    reaches ``clip_after`` the NEXT steps additionally clip gradients to
    ``clip_norm`` (tighter than the optimizer's own clip) until a step
    lands finite.  Escalation past clipping -- rollback to the last
    committed checkpoint -- is loop-side: feed ``metrics["guard_bad"]`` to
    ``repro.ft.GuardState`` (see ``launch/train.py``).
    """
    clip_after: int = 2
    clip_norm: float = 0.5


def make_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                    *, total_steps: int = 10000, warmup: int = 100,
                    schedule_name: str | None = None,
                    accum_steps: int = 1,
                    compress_grads: bool = False,
                    conv_policy=None,
                    conv_mesh=None,
                    conv_mode: str | None = None,
                    loss: Callable | None = None,
                    guard: GuardConfig | bool | None = None) -> Callable:
    """compress_grads: int8-quantize gradients with error feedback before
    the optimizer -- models the numerics of a compressed cross-pod gradient
    all-reduce (the EF residual rides in opt_state['ef']).

    conv_policy: override ``cfg.conv_policy`` for every conv layer in the
    model -- an ``EnginePolicy``, a policy string
    (``"fwd=pallas,dgrad=auto,wgrad=bp_phase"``), or a uniform engine name.
    jax.grad inside this step then dispatches each conv pass through the
    per-pass engines via the conv2d custom_vjp, so one training step can
    mix engines across forward / input-grad / weight-grad.

    conv_mesh: a ``repro.dist.ConvParallel`` or a sharding policy name
    (``"tp"`` / ``"dp_only"`` / ``"spatial"``) -- every conv traced inside
    the step then lowers through ``repro.dist.conv_parallel``'s sharded
    shard_map passes against the mesh active at trace time (halo exchange
    for spatial shards, per-pass psum placement).  Layers the mesh cannot
    shard fall back to the single-device path with the reason recorded in
    ``dispatch_events``.  None (the default) leaves convs unsharded.

    conv_mode: DEPRECATED uniform spelling of the same override.

    loss: ``(params, batch, cfg) -> (loss, metrics)`` plugin replacing the
    default LM loss -- e.g. ``repro.models.model.autoencoder_loss`` with an
    ``AutoencoderConfig`` (any frozen dataclass carrying ``name`` /
    ``conv_policy`` / ``conv_mode`` works as ``cfg`` then); the optimizer,
    schedules, accumulation and gradient compression apply unchanged.

    guard: a :class:`GuardConfig` (or ``True`` for the defaults) arms the
    in-graph numerical guard -- non-finite steps are skipped, a
    consecutive-bad streak escalates to tighter gradient clipping, and
    ``metrics`` gain ``guard_bad`` / ``guard_streak`` / ``guard_clipped``.
    ``None``/``False`` (the default) compiles the exact unguarded step."""
    if loss is None:
        loss = loss_fn
    if guard is True:
        guard = GuardConfig()
    elif guard is False:
        guard = None
    if conv_mode is not None:
        warnings.warn(
            "make_train_step(conv_mode=...) is deprecated; pass "
            "conv_policy=<EnginePolicy | policy string | engine name>",
            DeprecationWarning, stacklevel=2)
        if conv_policy is not None:
            raise TypeError("pass either conv_policy= or the deprecated "
                            "conv_mode=, not both")
        conv_policy = conv_mode
    if conv_policy is not None:
        # conv_mode=None: the override must win even over a cfg that still
        # sets the deprecated field.
        cfg = dataclasses.replace(cfg, conv_policy=str(conv_policy),
                                  conv_mode=None)
    sched_name = schedule_name or schedule.default_schedule_for(cfg.name)
    sched = schedule.SCHEDULES[sched_name]

    def train_step(params, opt_state, batch, step):
        opt_in = opt_state            # pre-step state (the compress block
        # rebinds opt_state; the guard's skip-select must compare against
        # what actually entered the step)
        with conv_parallel.conv_mesh(conv_mesh):
            # Applies at trace time, exactly like conv_policy: the convs
            # inside value_and_grad lower onto shard_map while this step
            # is being traced (a jit cache hit re-uses the sharded graph).
            if accum_steps == 1:
                (loss_val, metrics), grads = jax.value_and_grad(
                    loss, has_aux=True)(params, batch, cfg)
            else:
                # Microbatch accumulation: batch split on the leading axis.
                def split(x):
                    b = x.shape[0]
                    return x.reshape(accum_steps, b // accum_steps,
                                     *x.shape[1:])
                micro = jax.tree.map(split, batch)

                def acc_fn(carry, mb):
                    g_acc, l_acc = carry
                    (l, m), g = jax.value_and_grad(
                        loss, has_aux=True)(params, mb, cfg)
                    g_acc = jax.tree.map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), m

                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss_val), ms = jax.lax.scan(
                    acc_fn, (zero_g, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g: g / accum_steps, grads)
                loss_val = loss_val / accum_steps
                metrics = jax.tree.map(lambda x: x.mean(), ms)

        # Fault injection on the gradient VALUES must live in-graph: the
        # armed steps are read at trace time, the step comparison runs on
        # device -- so a jitted step still poisons exactly step N.
        nan_steps = inject.value_fault_steps("grad.values")
        if nan_steps is not None:
            factor = inject.nan_factor(step, nan_steps)
            grads = jax.tree.map(lambda g: g * factor, grads)

        if compress_grads:
            from repro.optim import compression
            ef = opt_state.get("ef") or jax.tree.map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            grads = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                                 grads, ef)
            key = jax.random.fold_in(jax.random.PRNGKey(17), step)
            q, residual = compression.compress_tree_int8(grads, key)
            grads = compression.decompress_tree_int8(q)
            opt_state = {**opt_state, "ef": residual}

        if guard is not None:
            streak0 = opt_state.get("guard_streak",
                                    jnp.zeros((), jnp.int32))
            gnorm = adamw.global_norm(grads)
            # One cheap reduction catches every inf/NaN leaf: a single
            # non-finite value makes the sqrt-of-sum-of-squares non-finite.
            finite = jnp.isfinite(loss_val) & jnp.isfinite(gnorm)
            clipping = streak0 >= guard.clip_after
            gscale = jnp.where(
                clipping,
                jnp.minimum(1.0, guard.clip_norm / jnp.maximum(gnorm, 1e-12)),
                1.0)
            grads = jax.tree.map(lambda g: g * gscale, grads)

        lr = sched(step + 1, peak_lr=opt_cfg.peak_lr, warmup=warmup,
                   total=total_steps)
        new_params, new_opt, opt_metrics = adamw.apply_updates(
            params, grads,
            {k: v for k, v in opt_state.items()
             if k not in ("ef", "guard_streak")},
            lr, opt_cfg)
        if compress_grads:
            new_opt["ef"] = opt_state["ef"]
        metrics = {**metrics, **opt_metrics}

        if guard is not None:
            # Skip-step select: a non-finite step passes params and
            # optimizer state through unchanged.  Missing old keys (e.g.
            # "ef" on the very first compressed step) select against
            # zeros, never against a NaN-tainted new value.
            def keep_old(new, old):
                return jax.tree.map(
                    lambda n, o: jnp.where(finite, n, o), new, old)
            new_params = keep_old(new_params, params)
            new_opt = {
                k: keep_old(v, opt_in[k] if k in opt_in
                            else jax.tree.map(jnp.zeros_like, v))
                for k, v in new_opt.items()}
            streak = jnp.where(finite, 0, streak0 + 1)
            new_opt["guard_streak"] = streak
            metrics = {**metrics,
                       "guard_bad": (~finite).astype(jnp.float32),
                       "guard_streak": streak.astype(jnp.float32),
                       "guard_clipped":
                           (clipping & finite).astype(jnp.float32)}
        return new_params, new_opt, metrics

    return train_step
