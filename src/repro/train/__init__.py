from repro.train.train_step import make_train_step, loss_fn
from repro.train.losses import train_loss, softmax_xent

__all__ = ["make_train_step", "loss_fn", "train_loss", "softmax_xent"]
