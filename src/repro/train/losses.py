"""Loss functions: token CE (with z-loss), MoE aux weighting, MTP aux head."""

from __future__ import annotations

import jax
import jax.numpy as jnp

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-4
MTP_WEIGHT = 0.3
Z_LOSS_WEIGHT = 1e-4


def softmax_xent(logits, targets, mask=None):
    """Mean CE over (optionally masked) positions; logits f32-promoted."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = logz - gold
    zl = Z_LOSS_WEIGHT * logz ** 2
    per_tok = ce + zl
    if mask is not None:
        per_tok = per_tok * mask
        return per_tok.sum() / jnp.maximum(mask.sum(), 1.0)
    return per_tok.mean()


def train_loss(logits, aux, batch):
    """Total loss: CE + MoE aux + MTP (predicting t+2 where defined)."""
    loss = softmax_xent(logits, batch["targets"], batch.get("loss_mask"))
    metrics = {"ce": loss}
    if "moe_lb" in aux:
        loss = loss + MOE_LB_WEIGHT * aux["moe_lb"] + MOE_Z_WEIGHT * aux["moe_z"]
        metrics["moe_lb"] = aux["moe_lb"]
    if "mtp_logits" in aux:
        # MTP head at position t predicts token t+2 = targets shifted by 1.
        t2 = jnp.roll(batch["targets"], -1, axis=1)
        mask = jnp.ones_like(t2, jnp.float32).at[:, -1].set(0.0)
        if "loss_mask" in batch:
            mask = mask * batch["loss_mask"]
        mtp = softmax_xent(aux["mtp_logits"], t2, mask)
        loss = loss + MTP_WEIGHT * mtp
        metrics["mtp_ce"] = mtp
    metrics["loss"] = loss
    return loss, metrics
