"""Slotted KV-cache ops for continuous batching.

Every decode cache in every family -- GQA/MLA key-value caches, Mamba2
SSM + conv states, RG-LRU recurrent + conv states -- is a pytree whose
leaves are laid out ``(n_layers, batch, ...)``: batch is ALWAYS axis 1
(see ``models.transformer.init_cache``).  A "lane" is therefore one index
on axis 1 across every leaf, and slot surgery is a tree-map.

Both ops take the lane index as a TRACED scalar, so one jitted program
serves every slot -- admitting a request into lane 3 runs the same
compiled insert as lane 0 (the tentpole requirement: lane insert resets
exactly one lane's cache slice without recompiling).
"""

from __future__ import annotations

import jax

BATCH_AXIS = 1          # every cache leaf: (n_layers, batch, ...)


def lane_insert(cache, src, lane):
    """Write the batch-1 cache ``src`` (a freshly prefilled request) into
    slot ``lane`` of the batched ``cache``.

    Overwrites the lane's ENTIRE slice on every leaf -- positions beyond
    the prompt come from ``src``'s zero-initialized tail -- so a recycled
    lane needs no separate scrub: whatever the previous occupant left
    behind is gone after one insert."""
    return jax.tree.map(
        lambda c, s: c.at[:, lane].set(s[:, 0].astype(c.dtype)), cache, src)


def lane_reset(cache, lane):
    """Zero slot ``lane``'s slice across every leaf (explicit scrub for a
    freed lane; :func:`lane_insert` makes it redundant on reuse, but the
    tests use it to prove a lane's slice is exactly the fresh state)."""
    return jax.tree.map(lambda c: c.at[:, lane].set(0), cache)
