"""The serving request record shared by both engines.

A request's lifecycle is submit -> (queue wait) -> prefill -> decode ->
finalize.  ``status`` records how it ended:

``"ok"``         completed with ``len(out) == max_new`` (or hit the
                 engine's ``max_len`` ceiling with partial output)
``"timed_out"``  its ``deadline_s`` wall-clock budget expired -- at
                 admission time (never decoded) or mid-stream (keeps the
                 tokens generated so far)
``"failed"``     its prefill or its decode lane crashed (e.g. an armed
                 ``serve.prefill`` / ``serve.decode`` fault) -- the
                 request is finalized with partial output instead of the
                 crash killing the whole batch

``t_submit`` / ``t_done`` are engine-clock stamps (injectable clock, see
the engines), so ``t_done - t_submit`` is the request latency the serving
benchmark aggregates into p50/p99.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: wall-clock budget from ``submit()`` in seconds; ``None`` = no limit.
    #: An overdue request is finalized with whatever tokens it has and
    #: ``status="timed_out"`` -- a slow batch degrades THAT request, not
    #: the whole batch.
    deadline_s: float | None = None
    status: str = "ok"
    t_submit: float = 0.0
    t_done: float | None = None
