"""Batched serving engine: wave-batched decode over a shared KV cache.

The engine admits up to ``max_batch`` requests per wave.  Prompts in a wave
are left-padded to a common length, prefilled in lockstep through the decode
path (uniform position clock -- cache layouts stay identical to the dry-run's
``serve_step``), then decoded greedily/sampled until every request finishes.
New waves are admitted as the queue refills.

This is deliberately the static-batching design: one positional clock per
wave means no per-lane gather/scatter in the cache update, which is exactly
the serve_step the production dry-run lowers.  (Continuous batching would
vmap per-lane positions; measured here to cost an extra scatter per step and
left as a documented extension.)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 pad_id: int = 0, seed: int = 0, conv_policy=None):
        """``conv_policy``: per-pass conv engine override for the decode
        path (EnginePolicy, policy string, or uniform engine name) --
        serving can pin e.g. a forward-only engine without touching the
        training config."""
        assert not cfg.is_encoder_only, "encoder-only archs do not decode"
        if conv_policy is not None:
            cfg = dataclasses.replace(cfg, conv_policy=str(conv_policy),
                                      conv_mode=None)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.pad_id = pad_id
        self.queue: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    def submit(self, req: Request):
        self.queue.append(req)

    def _run_wave(self, wave: list[Request]) -> None:
        b = self.max_batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len)
        # Lockstep prefill through the decode path.
        logits = None
        for t in range(plen):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks[:, t]),
                                         jnp.int32(t))
        pos = plen
        max_new = max(r.max_new for r in wave)
        for _ in range(min(max_new, self.max_len - plen)):
            lg = np.asarray(logits, np.float32)
            nxt = np.zeros(b, np.int32)
            for i, r in enumerate(wave):
                if r.done:
                    nxt[i] = self.pad_id
                    continue
                if self.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    tok = int(jax.random.categorical(
                        sub, jnp.asarray(lg[i]) / self.temperature))
                else:
                    tok = int(lg[i].argmax())
                r.out.append(tok)
                nxt[i] = tok
                if len(r.out) >= r.max_new:
                    r.done = True
            if all(r.done for r in wave):
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(nxt), jnp.int32(pos))
            pos += 1
        for r in wave:
            r.done = True

    def run(self) -> list[Request]:
        """Drain the queue; returns finished requests."""
        finished: list[Request] = []
        while self.queue:
            wave = [self.queue.pop(0)
                    for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            finished.extend(wave)
        return finished
