"""Batched serving engine: wave-batched decode over a shared KV cache.

The engine admits up to ``max_batch`` requests per wave.  Prompts in a wave
are left-padded to a common length, prefilled in lockstep through the decode
path (uniform position clock -- cache layouts stay identical to the dry-run's
``serve_step``), then decoded greedily/sampled until every request finishes.
New waves are admitted as the queue refills.

This is deliberately the static-batching design: one positional clock per
wave means no per-lane gather/scatter in the cache update, which is exactly
the serve_step the production dry-run lowers.  (Continuous batching would
vmap per-lane positions; measured here to cost an extra scatter per step and
left as a documented extension.)
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    #: wall-clock budget from ``submit()`` in seconds; ``None`` = no limit.
    #: An overdue request is finalized with whatever tokens it has and
    #: ``status="timed_out"`` -- a slow wave degrades THAT request, not the
    #: whole batch.
    deadline_s: float | None = None
    status: str = "ok"
    t_submit: float = 0.0


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 pad_id: int = 0, seed: int = 0, conv_policy=None,
                 clock=time.monotonic):
        """``conv_policy``: per-pass conv engine override for the decode
        path (EnginePolicy, policy string, or uniform engine name) --
        serving can pin e.g. a forward-only engine without touching the
        training config.

        ``clock``: zero-arg wall-clock (seconds) used for request
        deadlines; injectable for deterministic tests."""
        assert not cfg.is_encoder_only, "encoder-only archs do not decode"
        if conv_policy is not None:
            cfg = dataclasses.replace(cfg, conv_policy=str(conv_policy),
                                      conv_mode=None)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.pad_id = pad_id
        self.queue: list[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self.clock = clock
        self.counters = {"completed": 0, "timed_out": 0, "waves": 0}
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))

    def submit(self, req: Request):
        req.t_submit = self.clock()
        self.queue.append(req)

    def _expire(self, wave: list[Request]) -> None:
        """Finalize overdue requests: keep the tokens generated so far,
        mark ``status="timed_out"``."""
        now = self.clock()
        for r in wave:
            if (not r.done and r.deadline_s is not None
                    and now - r.t_submit > r.deadline_s):
                r.done = True
                r.status = "timed_out"
                self.counters["timed_out"] += 1

    def run_summary(self) -> dict:
        """Counters of the engine's lifetime: completed / timed_out
        requests and waves run."""
        return dict(self.counters)

    def _run_wave(self, wave: list[Request]) -> None:
        self.counters["waves"] += 1
        self._expire(wave)            # queue wait may already be overdue
        b = self.max_batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len)
        # Lockstep prefill through the decode path.
        logits = None
        for t in range(plen):
            if all(r.done for r in wave):
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(toks[:, t]),
                                         jnp.int32(t))
        pos = plen
        max_new = max(r.max_new for r in wave)
        self._expire(wave)
        for _ in range(min(max_new, self.max_len - plen)):
            if logits is None or all(r.done for r in wave):
                break
            lg = np.asarray(logits, np.float32)
            nxt = np.zeros(b, np.int32)
            for i, r in enumerate(wave):
                if r.done:
                    nxt[i] = self.pad_id
                    continue
                if self.temperature > 0:
                    self.key, sub = jax.random.split(self.key)
                    tok = int(jax.random.categorical(
                        sub, jnp.asarray(lg[i]) / self.temperature))
                else:
                    tok = int(lg[i].argmax())
                r.out.append(tok)
                nxt[i] = tok
                if len(r.out) >= r.max_new:
                    r.done = True
            self._expire(wave)        # deadline checked after every token
            if all(r.done for r in wave):
                break
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(nxt), jnp.int32(pos))
            pos += 1
        for r in wave:
            if not r.done:
                r.done = True
            if r.status == "ok":
                self.counters["completed"] += 1

    def run(self) -> list[Request]:
        """Drain the queue; returns finished requests."""
        finished: list[Request] = []
        while self.queue:
            wave = [self.queue.pop(0)
                    for _ in range(min(self.max_batch, len(self.queue)))]
            self._run_wave(wave)
            finished.extend(wave)
        return finished
