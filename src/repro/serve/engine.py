"""Batched serving engine: wave-batched decode over a shared KV cache.

The engine admits up to ``max_batch`` requests per wave.  Prompts in a wave
are left-padded to a common length, prefilled in lockstep through the decode
path (uniform position clock -- cache layouts stay identical to the dry-run's
``serve_step``), then decoded greedily/sampled until every request finishes.
New waves are admitted as the queue refills.

This is the static-batching design: one positional clock per wave means no
per-lane gather/scatter in the cache update, but every wave burns decode
steps on finished and padded lanes and new requests wait at wave boundaries.
:mod:`repro.serve.continuous` is the slotted-cache engine that retires that
waste; this one stays as the lockstep baseline the serving benchmark
(``benchmarks/bench_serve.py``) and the token-equivalence tests compare
against.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models import transformer as T
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.request import Request
from repro.serve.sampling import make_sampler

__all__ = ["Engine", "Request", "SUMMARY_COUNTERS", "merged_summary"]

#: the shared counter vocabulary of BOTH engines' run_summary: every key is
#: present in every summary (0 when the engine has no such phase -- the
#: static engine never "inserts", the continuous engine has no "waves"),
#: so the two engines are directly diffable.
SUMMARY_COUNTERS = ("completed", "timed_out", "failed", "admitted",
                    "inserts", "waves", "decode_steps")


def merged_summary(engine_kind: str, counters: dict, stats: dict) -> dict:
    """One FLAT summary dict merging lifetime ``counters`` and phase
    ``stats`` (prefill_s/decode_s/tokens...), under the shared
    :data:`SUMMARY_COUNTERS` vocabulary."""
    out: dict = {"engine_kind": engine_kind}
    for key in SUMMARY_COUNTERS:
        out[key] = counters.get(key, 0)
    for key, val in counters.items():       # engine-specific extras survive
        out.setdefault(key, val)
    for key, val in stats.items():
        out[key] = round(val, 6) if isinstance(val, float) else val
    return out


class Engine:
    #: introspection anchor mirroring ContinuousEngine.engine_kind, so
    #: summaries and metrics lines name their producer.
    engine_kind = "static"
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 pad_id: int = 0, seed: int = 0, conv_policy=None,
                 clock=time.monotonic):
        """``conv_policy``: per-pass conv engine override for the decode
        path (EnginePolicy, policy string, or uniform engine name) --
        serving can pin e.g. a forward-only engine without touching the
        training config.

        ``clock``: zero-arg wall-clock (seconds) used for request
        deadlines; injectable for deterministic tests."""
        assert not cfg.is_encoder_only, "encoder-only archs do not decode"
        if conv_policy is not None:
            cfg = dataclasses.replace(cfg, conv_policy=str(conv_policy),
                                      conv_mode=None)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.pad_id = pad_id
        self.queue: collections.deque[Request] = collections.deque()
        self.key = jax.random.PRNGKey(seed)
        self.clock = clock
        self.counters = {"completed": 0, "timed_out": 0, "waves": 0,
                         "decode_steps": 0}
        #: wall-clock phase accounting for the serving benchmark:
        #: prefill/decode seconds, prompt tokens prefilled, generated
        #: tokens, and lane_steps = sum over decode steps of lanes that
        #: were still generating (lane_steps / (decode_steps * max_batch)
        #: is the wave engine's occupancy -- the waste continuous
        #: batching removes).
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_tokens": 0, "tokens": 0, "lane_steps": 0}
        #: optional hook called after every decode step (the benchmark's
        #: open-loop arrival driver submits mid-wave arrivals here).
        self.on_step = None
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))
        self._sample = make_sampler(temperature)

    def submit(self, req: Request):
        req.t_submit = self.clock()
        self.queue.append(req)

    def _finalize(self, req: Request, status: str | None = None) -> None:
        req.done = True
        if status is not None:
            req.status = status
        req.t_done = self.clock()
        key = req.status if req.status != "ok" else "completed"
        self.counters[key] = self.counters.get(key, 0) + 1
        latency = req.t_done - req.t_submit
        obs_events.emit("serve", f"finalize:{key}", engine=self.engine_kind,
                        rid=req.rid, latency_s=round(latency, 6),
                        tokens=len(req.out))
        obs_metrics.record_latency(latency)

    def _expire(self, wave: list[Request]) -> None:
        """Finalize overdue requests: keep the tokens generated so far,
        mark ``status="timed_out"``."""
        now = self.clock()
        for r in wave:
            if (not r.done and r.deadline_s is not None
                    and now - r.t_submit > r.deadline_s):
                self._finalize(r, "timed_out")

    def run_summary(self) -> dict:
        """Flat lifetime summary: the shared counter vocabulary
        (:data:`SUMMARY_COUNTERS`) merged with the phase ``stats``, keyed
        identically to the continuous engine so the two are diffable."""
        return merged_summary(self.engine_kind, self.counters, self.stats)

    def _tick(self) -> None:
        if self.on_step is not None:
            self.on_step(self)

    def _run_wave(self, wave: list[Request]) -> None:
        self.counters["waves"] += 1
        obs_events.emit("serve", "wave", engine=self.engine_kind,
                        size=len(wave))
        b = self.max_batch
        plen = max(len(r.prompt) for r in wave)
        toks = np.full((b, plen), self.pad_id, np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt   # left-pad
        cache = T.init_cache(self.cfg, b, self.max_len)
        # Lockstep prefill through the decode path.
        logits = None
        t0 = time.perf_counter()
        with obs_trace.span("serve:prefill", engine=self.engine_kind,
                            size=len(wave), plen=plen):
            for t in range(plen):
                if all(r.done for r in wave):
                    break
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(toks[:, t]),
                                             jnp.int32(t))
                self._tick()
            if logits is not None:
                jax.block_until_ready(logits)
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += sum(len(r.prompt) for r in wave)
        pos = plen
        max_new = max(r.max_new for r in wave)
        self._expire(wave)
        for _ in range(min(max_new, self.max_len - plen)):
            if logits is None or all(r.done for r in wave):
                break
            # Sample ON DEVICE (greedy argmax / batched categorical) and
            # transfer only the B token ids, not the (B, V) logits.
            self.key, sub = jax.random.split(self.key)
            sampled = np.asarray(self._sample(logits, sub))
            nxt = np.full(b, self.pad_id, np.int32)
            active = 0
            for i, r in enumerate(wave):
                if r.done:
                    continue
                active += 1
                tok = int(sampled[i])
                r.out.append(tok)
                nxt[i] = tok
                if len(r.out) >= r.max_new:
                    self._finalize(r)
            self.stats["tokens"] += active
            self.stats["lane_steps"] += active
            self._expire(wave)        # deadline checked after every token
            if all(r.done for r in wave):
                break
            t0 = time.perf_counter()
            with obs_trace.span("serve:decode", engine=self.engine_kind,
                                pos=pos, active=active):
                logits, cache = self._decode(self.params, cache,
                                             jnp.asarray(nxt),
                                             jnp.int32(pos))
                jax.block_until_ready(logits)
            self.stats["decode_s"] += time.perf_counter() - t0
            self.counters["decode_steps"] += 1
            obs_metrics.serve_tick(self)
            self._tick()
            pos += 1
        for r in wave:
            if not r.done:
                self._finalize(r)

    def _admit_wave(self) -> tuple[list[Request], list[Request]]:
        """Pop the next wave off the queue; requests whose deadline
        already expired while queued are finalized HERE (admission-time
        expiry) and never burn a decode step."""
        wave: list[Request] = []
        expired: list[Request] = []
        now = self.clock()
        while self.queue and len(wave) < self.max_batch:
            r = self.queue.popleft()
            if (r.deadline_s is not None
                    and now - r.t_submit > r.deadline_s):
                self._finalize(r, "timed_out")
                expired.append(r)
                continue
            wave.append(r)
        return wave, expired

    def run(self) -> list[Request]:
        """Drain the queue; returns finished requests."""
        finished: list[Request] = []
        while self.queue:
            wave, expired = self._admit_wave()
            finished.extend(expired)
            if wave:
                self._run_wave(wave)
                finished.extend(wave)
        return finished
