from repro.serve.continuous import ContinuousEngine
from repro.serve.engine import Engine
from repro.serve.request import Request

__all__ = ["ContinuousEngine", "Engine", "Request"]
