"""On-device token sampling for the serving engines.

Per-token sampling used to round-trip the full (B, V) logits to host and
loop over lanes in Python; the samplers here run argmax / categorical ON
DEVICE so the host transfer per step is B token ids.  Greedy (temperature
0) is a plain argmax -- deterministic, the engines' token-equivalence
tests anchor on it.  Temperature sampling draws one batched categorical
per step (independent Gumbel noise per lane from a single key).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def make_sampler(temperature: float):
    """jitted ``(logits (B, V), key) -> token ids (B,) int32``."""
    if temperature <= 0:
        @jax.jit
        def sample(logits, key):
            del key
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    else:
        t = float(temperature)

        @jax.jit
        def sample(logits, key):
            return jax.random.categorical(
                key, logits.astype(jnp.float32) / t, axis=-1
            ).astype(jnp.int32)
    return sample
