"""Continuous-batching engine: prefill -> insert-into-slot -> generate.

Wave batching (:mod:`repro.serve.engine`) is the serving-side analogue of
the zero-space waste the paper kills in the conv datapath: every decode
step runs all ``max_batch`` lanes even after most finished, and a request
arriving mid-wave waits for the wave boundary.  This engine keeps a
SLOTTED KV cache with PER-LANE position clocks instead:

* ``submit`` enqueues; admission happens the moment a lane frees -- the
  request's prompt is prefilled in ONE scanned dispatch onto a fresh
  batch-1 cache (``models.model.prefill``) and
  :func:`repro.serve.cache.lane_insert` writes that cache into the freed
  slot while the other lanes keep their state.
* the decode step takes a per-lane ``(B,)`` position vector (the
  ``models.attention`` per-lane path: rope angles, cache scatter and
  causal masking all per lane), so lanes at wildly different depths share
  one jitted program.
* exactly three programs are compiled, once each: the prefill scan (per
  prompt length), the lane insert, and the decode step -- admission never
  recompiles anything, which is the tentpole contract.

Failure domain: the ``serve.prefill`` / ``serve.decode`` fault sites
(``repro.ft.inject``) fire per request / per lane.  A crashing prefill or
decode lane finalizes THAT request with ``status="failed"`` and frees its
slot -- the rest of the batch keeps serving.  The engine advances the
injection step clock once per decode step, so ``@stepN`` rules target
exact serving steps.

The conv-bearing decode archs (Mamba2 / RecurrentGemma causal conv1d)
ride the same path: their decode states are position-free, so only the
slot surgery applies, and ``conv_policy`` carries over from the static
engine unchanged.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.ft import inject
from repro.models import model as M
from repro.models import transformer as T
from repro.obs import events as obs_events
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve import cache as C
from repro.serve.engine import merged_summary
from repro.serve.request import Request
from repro.serve.sampling import make_sampler

__all__ = ["ContinuousEngine", "Request"]


class ContinuousEngine:
    #: introspection anchor for the benchmark's no-fallback gate: a driver
    #: that silently handed the workload to the wave engine cannot fake
    #: this together with the ``inserts`` counter.
    engine_kind = "continuous"

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, temperature: float = 0.0,
                 pad_id: int = 0, seed: int = 0, conv_policy=None,
                 clock=time.monotonic):
        """Same surface as the static :class:`repro.serve.engine.Engine`
        (``conv_policy`` pins the decode path's per-pass conv engines,
        ``clock`` is the injectable deadline clock)."""
        assert not cfg.is_encoder_only, "encoder-only archs do not decode"
        if conv_policy is not None:
            cfg = dataclasses.replace(cfg, conv_policy=str(conv_policy),
                                      conv_mode=None)
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.temperature = temperature
        self.pad_id = pad_id
        self.queue: collections.deque[Request] = collections.deque()
        self.key = jax.random.PRNGKey(seed)
        self.clock = clock
        # Slotted state: lane i of the batched cache belongs to lanes[i];
        # lane_pos is the per-lane position clock (the NEXT cache slot the
        # lane writes), next_tok the last sampled token to feed.
        self.cache = T.init_cache(cfg, max_batch, max_len)
        self.lanes: list[Request | None] = [None] * max_batch
        self.lane_pos = np.zeros(max_batch, np.int32)
        self.next_tok = np.full(max_batch, pad_id, np.int32)
        self.counters = {"completed": 0, "timed_out": 0, "failed": 0,
                         "admitted": 0, "inserts": 0, "decode_steps": 0}
        #: phase accounting for the serving benchmark (same keys as the
        #: static engine's ``stats``).
        self.stats = {"prefill_s": 0.0, "decode_s": 0.0,
                      "prefill_tokens": 0, "tokens": 0, "lane_steps": 0}
        #: optional hook called after every decode step (the benchmark's
        #: open-loop arrival driver submits new arrivals here).
        self.on_step = None
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg))
        self._prefill = jax.jit(
            lambda p, toks: M.prefill(p, toks, cfg, max_len))
        self._insert = jax.jit(C.lane_insert)
        self._sample = make_sampler(temperature)

    # -- submission / finalization ------------------------------------------

    def submit(self, req: Request):
        req.t_submit = self.clock()
        self.queue.append(req)

    def _finalize(self, req: Request, status: str | None = None) -> None:
        req.done = True
        if status is not None:
            req.status = status
        req.t_done = self.clock()
        key = req.status if req.status != "ok" else "completed"
        self.counters[key] = self.counters.get(key, 0) + 1
        latency = req.t_done - req.t_submit
        obs_events.emit("serve", f"finalize:{key}", engine=self.engine_kind,
                        rid=req.rid, latency_s=round(latency, 6),
                        tokens=len(req.out))
        obs_metrics.record_latency(latency)

    def run_summary(self) -> dict:
        """Flat lifetime summary: counters AND phase stats merged under the
        shared vocabulary (``serve.engine.SUMMARY_COUNTERS``), so static
        and continuous summaries diff key-for-key."""
        return merged_summary(self.engine_kind, self.counters, self.stats)

    def free_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lanes) if r is None]

    def active_lanes(self) -> list[int]:
        return [i for i, r in enumerate(self.lanes) if r is not None]

    # -- admission: prefill -> insert-into-slot -----------------------------

    def _sample_one(self, logits) -> int:
        self.key, sub = jax.random.split(self.key)
        return int(np.asarray(self._sample(logits, sub))[0])

    def _admit(self, finished: list[Request]) -> None:
        """Fill every free lane from the queue head.  Deadline-expired
        queue entries are finalized at admission time (no decode step is
        ever spent on them); a crashing prefill finalizes that request
        with ``status="failed"`` and moves on."""
        for lane in self.free_lanes():
            while self.queue:
                req = self.queue.popleft()
                now = self.clock()
                if (req.deadline_s is not None
                        and now - req.t_submit > req.deadline_s):
                    self._finalize(req, "timed_out")
                    finished.append(req)
                    continue
                try:
                    inject.fault_point("serve.prefill")
                    t0 = time.perf_counter()
                    with obs_trace.span("serve:prefill",
                                        engine=self.engine_kind,
                                        rid=req.rid,
                                        plen=len(req.prompt)):
                        logits, src = self._prefill(
                            self.params,
                            jnp.asarray([req.prompt], jnp.int32))
                        jax.block_until_ready(logits)
                    self.stats["prefill_s"] += time.perf_counter() - t0
                except Exception:
                    self._finalize(req, "failed")
                    finished.append(req)
                    continue
                self.counters["admitted"] += 1
                obs_events.emit("serve", "admit", engine=self.engine_kind,
                                rid=req.rid, lane=lane,
                                plen=len(req.prompt))
                self.stats["prefill_tokens"] += len(req.prompt)
                tok = self._sample_one(logits)
                req.out.append(tok)
                self.stats["tokens"] += 1
                if len(req.out) >= req.max_new:
                    # Single-token request: done straight out of prefill;
                    # the lane stays free for the next queue entry.
                    self._finalize(req)
                    finished.append(req)
                    continue
                # The insert is part of the admission cost (prefill_s), not
                # the decode rate: block here so its full-cache copy is not
                # charged to the next decode step's timer.
                t0 = time.perf_counter()
                with obs_trace.span("serve:insert",
                                    engine=self.engine_kind,
                                    rid=req.rid, lane=lane):
                    self.cache = self._insert(self.cache, src,
                                              jnp.int32(lane))
                    jax.block_until_ready(self.cache)
                self.stats["prefill_s"] += time.perf_counter() - t0
                self.counters["inserts"] += 1
                obs_events.emit("serve", "insert", engine=self.engine_kind,
                                rid=req.rid, lane=lane)
                self.lanes[lane] = req
                self.lane_pos[lane] = len(req.prompt)
                self.next_tok[lane] = tok
                break

    # -- generate: one decode step over every occupied lane -----------------

    def _release(self, lane: int, finished: list[Request],
                 status: str | None = None) -> None:
        self._finalize(self.lanes[lane], status)
        finished.append(self.lanes[lane])
        self.lanes[lane] = None

    def step(self, finished: list[Request]) -> bool:
        """One decode step across all occupied lanes (per-lane position
        vector); samples on device, advances each lane's clock, finalizes
        lanes that completed / timed out / failed.  Returns False when no
        lane is occupied."""
        active = self.active_lanes()
        if not active:
            return False
        self.counters["decode_steps"] += 1
        inject.set_step(self.counters["decode_steps"])
        t0 = time.perf_counter()
        with obs_trace.span("serve:decode", engine=self.engine_kind,
                            active=len(active)):
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.next_tok),
                jnp.asarray(self.lane_pos))
            jax.block_until_ready(logits)
        self.stats["decode_s"] += time.perf_counter() - t0
        self.key, sub = jax.random.split(self.key)
        sampled = np.asarray(self._sample(logits, sub))
        self.stats["lane_steps"] += len(active)
        now = self.clock()
        for i in active:
            r = self.lanes[i]
            try:
                inject.fault_point("serve.decode")
            except inject.InjectedFault:
                self._release(i, finished, "failed")
                continue
            tok = int(sampled[i])
            r.out.append(tok)
            self.stats["tokens"] += 1
            self.next_tok[i] = tok
            self.lane_pos[i] += 1
            if len(r.out) >= r.max_new or self.lane_pos[i] >= self.max_len:
                self._release(i, finished)
            elif (r.deadline_s is not None
                    and now - r.t_submit > r.deadline_s):
                self._release(i, finished, "timed_out")
        obs_metrics.serve_tick(self)
        if self.on_step is not None:
            self.on_step(self)
        return True

    def run(self) -> list[Request]:
        """Drain queue and lanes; returns finished requests.  Admission
        runs before every decode step, so a request is inserted the
        moment a lane frees -- never at a wave boundary."""
        finished: list[Request] = []
        while self.queue or self.active_lanes():
            self._admit(finished)
            self.step(finished)
        return finished
