"""Fault tolerance bookkeeping: heartbeats, straggler detection, restart plan.

On a real cluster the coordinator runs outside JAX; here the same logic is a
small deterministic library driven by the train loop, exercised by tests and
the example drivers:

  * HeartbeatTable -- per-worker liveness with a deadline; dead workers
    produce a RestartPlan (which mesh to rebuild, which checkpoint to load,
    which data step to resume from -- exact, thanks to the step-addressable
    pipeline).
  * StragglerDetector -- per-step wall-time EWMA; a worker slower than
    ``threshold`` x the fleet median for ``patience`` consecutive steps is
    flagged for preemptive eviction (slow-node mitigation, not just crash
    recovery).
  * ElasticPlan -- given survivors, choose the largest (data, model) mesh
    with model-dim preserved (TP degree must divide attention heads), so
    resumption reshards params via ckpt.restore(shardings=new).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class RestartPlan:
    failed_workers: list[int]
    resume_step: int
    mesh_shape: tuple[int, ...]
    note: str


class HeartbeatTable:
    def __init__(self, n_workers: int, timeout_s: float = 60.0):
        self.n = n_workers
        self.timeout = timeout_s
        self.last: dict[int, float] = {}

    def beat(self, worker: int, t: Optional[float] = None):
        self.last[worker] = time.monotonic() if t is None else t

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w in range(self.n)
                if now - self.last.get(w, -1e18) > self.timeout]


class StragglerDetector:
    def __init__(self, n_workers: int, threshold: float = 1.5,
                 patience: int = 5, alpha: float = 0.2):
        self.n = n_workers
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.ewma = [0.0] * n_workers
        self.strikes = [0] * n_workers

    def observe(self, step_times: list[float]) -> list[int]:
        """Feed per-worker step wall-times; returns workers to evict."""
        for w, t in enumerate(step_times):
            self.ewma[w] = (t if self.ewma[w] == 0.0
                            else (1 - self.alpha) * self.ewma[w]
                            + self.alpha * t)
        med = sorted(self.ewma)[self.n // 2]
        evict = []
        for w in range(self.n):
            if med > 0 and self.ewma[w] > self.threshold * med:
                self.strikes[w] += 1
                if self.strikes[w] >= self.patience:
                    evict.append(w)
            else:
                self.strikes[w] = 0
        return evict


def elastic_mesh(survivors: int, model_dim: int,
                 heads: int) -> tuple[int, int]:
    """Largest (data, model) mesh from `survivors` chips keeping TP valid.

    Model dim is kept if it still divides the head count; otherwise it is
    halved until it does.  Data dim = survivors // model, rounded to a
    power-of-two fraction so collectives stay ring-friendly.
    """
    m = model_dim
    while m > 1 and (heads % m != 0 or survivors < m):
        m //= 2
    d = survivors // m
    # round data dim down to a power of two for ring all-reduce regularity
    p = 1
    while p * 2 <= d:
        p *= 2
    return (p, m)


def make_restart_plan(hb: HeartbeatTable, ckpt_steps: list[int],
                      model_dim: int, heads: int,
                      now: Optional[float] = None) -> Optional[RestartPlan]:
    dead = hb.dead(now)
    if not dead:
        return None
    survivors = hb.n - len(dead)
    mesh = elastic_mesh(survivors, model_dim, heads)
    resume = ckpt_steps[-1] if ckpt_steps else 0
    return RestartPlan(
        failed_workers=dead, resume_step=resume, mesh_shape=mesh,
        note=f"rebuild mesh {mesh} from {survivors} survivors; "
             f"data pipeline resumes at step {resume} deterministically")
