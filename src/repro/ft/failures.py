"""Fault tolerance bookkeeping: heartbeats, straggler detection, restart plan.

On a real cluster the coordinator runs outside JAX; here the same logic is a
small deterministic library driven by the train loop, exercised by tests and
the example drivers:

  * HeartbeatTable -- per-worker liveness with a deadline; dead workers
    produce a RestartPlan (which mesh to rebuild, which checkpoint to load,
    which data step to resume from -- exact, thanks to the step-addressable
    pipeline).
  * StragglerDetector -- per-step wall-time EWMA; a worker slower than
    ``threshold`` x the fleet median for ``patience`` consecutive steps is
    flagged for preemptive eviction (slow-node mitigation, not just crash
    recovery).
  * ElasticPlan -- given survivors, choose the largest (data, model) mesh
    with model-dim preserved (TP degree must divide attention heads), so
    resumption reshards params via ckpt.restore(shardings=new).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class RestartPlan:
    failed_workers: list[int]
    resume_step: int
    mesh_shape: tuple[int, ...]
    note: str


class HeartbeatTable:
    """Per-worker liveness with a deadline.

    Intended semantics: a worker is dead when more than ``timeout_s`` has
    elapsed since its LAST heartbeat, where a worker that has never beaten
    counts as having beaten at table creation (``t0``) -- a freshly built
    fleet gets the full ``timeout_s`` grace period to report in, instead of
    being declared dead at t=0 before it had any chance to beat.

    ``t0`` / ``beat(t=)`` / ``dead(now=)`` take an explicit clock for
    deterministic tests; the default clock is ``time.monotonic()`` (do not
    mix the two in one table).
    """

    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 t0: Optional[float] = None):
        self.n = n_workers
        self.timeout = timeout_s
        self.t0 = time.monotonic() if t0 is None else t0
        self.last: dict[int, float] = {}

    def beat(self, worker: int, t: Optional[float] = None):
        self.last[worker] = time.monotonic() if t is None else t

    def dead(self, now: Optional[float] = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [w for w in range(self.n)
                if now - self.last.get(w, self.t0) > self.timeout]


class StragglerDetector:
    def __init__(self, n_workers: int, threshold: float = 1.5,
                 patience: int = 5, alpha: float = 0.2):
        self.n = n_workers
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.ewma = [0.0] * n_workers
        self.strikes = [0] * n_workers

    def observe(self, step_times: list[float]) -> list[int]:
        """Feed per-worker step wall-times; returns workers to evict."""
        for w, t in enumerate(step_times):
            self.ewma[w] = (t if self.ewma[w] == 0.0
                            else (1 - self.alpha) * self.ewma[w]
                            + self.alpha * t)
        med = sorted(self.ewma)[self.n // 2]
        evict = []
        for w in range(self.n):
            if med > 0 and self.ewma[w] > self.threshold * med:
                self.strikes[w] += 1
                if self.strikes[w] >= self.patience:
                    evict.append(w)
            else:
                self.strikes[w] = 0
        return evict


def elastic_mesh(survivors: int, model_dim: int,
                 heads: int) -> tuple[int, int]:
    """Largest (data, model) mesh from `survivors` chips keeping TP valid.

    Model dim is kept if it still divides the head count; otherwise it is
    halved until it does.  Data dim = survivors // model, rounded to a
    power-of-two fraction so collectives stay ring-friendly.
    """
    m = model_dim
    while m > 1 and (heads % m != 0 or survivors < m):
        m //= 2
    d = survivors // m
    # round data dim down to a power of two for ring all-reduce regularity
    p = 1
    while p * 2 <= d:
        p *= 2
    return (p, m)


@dataclasses.dataclass
class GuardState:
    """Loop-side escalation ladder for non-finite training steps.

    The jitted guard inside ``train_step`` (``make_train_step(guard=...)``)
    already DROPS a non-finite update in-graph -- params and optimizer
    state pass through unchanged -- and engages the tighter gradient clip
    once the in-graph streak reaches ``clip_after``.  This object mirrors
    the streak on the host (feed it ``metrics["guard_bad"]`` every step)
    and decides when to escalate past what the graph can do alone:

        'skip'      1 .. clip_after-1 consecutive bad steps (update was
                    dropped in-graph; nothing else to do)
        'clip'      clip_after .. rollback_after-1 (the graph is now
                    clipping; keep going)
        'rollback'  >= rollback_after -- restore the last committed
                    checkpoint (see :func:`make_guard_restart_plan`) and
                    call :meth:`rolled_back`
    """
    clip_after: int = 2
    rollback_after: int = 4
    bad_streak: int = 0
    total_bad: int = 0
    rollbacks: int = 0

    def observe(self, bad: bool) -> str:
        """Record one step's finiteness; returns the escalation action."""
        if not bad:
            self.bad_streak = 0
            return "ok"
        self.bad_streak += 1
        self.total_bad += 1
        if self.bad_streak >= self.rollback_after:
            return "rollback"
        if self.bad_streak >= self.clip_after:
            return "clip"
        return "skip"

    def rolled_back(self) -> None:
        self.rollbacks += 1
        self.bad_streak = 0


def make_guard_restart_plan(state: GuardState, ckpt_steps: list[int],
                            mesh_shape: tuple[int, ...] = (1, 1)) \
        -> RestartPlan:
    """The RestartPlan of a numerical-guard rollback: no worker died and
    the mesh survives unchanged -- resume from the newest committed
    checkpoint (step 0 / fresh init when none exists)."""
    resume = ckpt_steps[-1] if ckpt_steps else 0
    return RestartPlan(
        failed_workers=[], resume_step=resume, mesh_shape=mesh_shape,
        note=f"numerical guard: {state.bad_streak} consecutive non-finite "
             f"steps ({state.total_bad} total); restore checkpoint "
             f"{resume} and resume")


def make_restart_plan(hb: HeartbeatTable, ckpt_steps: list[int],
                      model_dim: int, heads: int,
                      now: Optional[float] = None) -> Optional[RestartPlan]:
    dead = hb.dead(now)
    if not dead:
        return None
    survivors = hb.n - len(dead)
    mesh = elastic_mesh(survivors, model_dim, heads)
    resume = ckpt_steps[-1] if ckpt_steps else 0
    return RestartPlan(
        failed_workers=dead, resume_step=resume, mesh_shape=mesh,
        note=f"rebuild mesh {mesh} from {survivors} survivors; "
             f"data pipeline resumes at step {resume} deterministically")
