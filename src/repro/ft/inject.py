"""Deterministic, config-driven fault injection (``repro.config.fault_spec``).

Every failure domain in the stack registers a NAMED SITE through the one
:func:`fault_point` helper -- the Pallas kernel launches, the plan-cache
read/write, the autotune timing harness, the checkpoint writer/reader,
the gradient values of the train step, and the continuous serving
engine's per-request prefill / per-lane decode (a crashing lane
finalizes that request with ``status="failed"`` instead of killing the
batch).  A fault spec arms rules against those sites:

    config.update(fault_spec="pallas.*:raise@step3;grad.values:nan@step5")

Grammar (``;``-separated rules)::

    <site-glob>:<action>[@step<N> | @<N>][~p<P>]

``site-glob``  fnmatch pattern over :data:`KNOWN_SITES` (must match >= 1)
``action``     ``raise`` -- raise :class:`InjectedFault` at the site;
               ``nan``   -- poison the value passing through the site
                            (floating leaves multiplied by NaN)
``@stepN``     fire only when the injection clock (:func:`set_step`, driven
               by the train loop) equals ``N``; omitted = every step
``~pP``        fire with probability ``P`` from a ``random.Random`` seeded
               by ``config.fault_seed`` at arm time -- deterministic per
               (spec, seed)

Zero overhead when disarmed: :func:`fault_point` is a single ``is None``
check, so the production hot path pays one attribute read per site.  The
injector records every firing (:func:`fired_events`) and every site it saw
while armed (:func:`seen_sites`) so CI can assert both the degradation
behaviour and the site coverage.

The config singleton re-arms the injector whenever ``fault_spec`` /
``fault_seed`` change (``config.update`` or the deprecated env mutation),
and this module syncs once at import, so either import order works.

Step-targeted rules and jit: dispatch-level sites fire at TRACE time, so a
``@stepN`` rule only hits a jitted train step if that step triggers a
(re)trace -- which is exactly the realistic failure (Mosaic lowering
errors happen at compile time).  Chaos drivers that want per-step dispatch
faults run the grad function eagerly.  ``grad.values`` is different: the
train step builds the NaN injection INTO the jitted graph
(:func:`value_fault_steps` + :func:`nan_factor`), so it fires on the exact
step regardless of jit caching.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import random
import re

from repro.core.config import config
from repro.obs import events as obs_events

#: every registered fault site.  Adding a ``fault_point`` call to a new
#: failure domain means adding its name here -- the coverage test asserts
#: the two stay in sync by exercising each domain.
KNOWN_SITES = frozenset({
    "pallas.forward.launch",      # kernels/ops.py: forward tap-GEMM launch
    "pallas.input_grad.launch",   # kernels/ops.py: fused phased launch
    "pallas.weight_grad.launch",  # kernels/ops.py: tap-wgrad launch
    "plan_cache.read",            # kernels/autotune.py: persistent store read
    "plan_cache.write",           # kernels/autotune.py: atomic store write
    "autotune.measure",           # kernels/autotune.py: candidate timing
    "ckpt.write",                 # ckpt/checkpoint.py: manifest+leaf writer
    "ckpt.read",                  # ckpt/checkpoint.py: restore
    "grad.values",                # train loops: the gradient pytree itself
    "serve.prefill",              # serve/continuous.py: per-request prefill
    "serve.decode",               # serve/continuous.py: per-lane decode step
})

ACTIONS = ("raise", "nan")


class InjectedFault(RuntimeError):
    """The exception :func:`fault_point` raises for a ``raise`` rule."""

    def __init__(self, site: str, rule: "FaultRule"):
        super().__init__(f"injected fault at {site!r} "
                         f"(rule {rule.pattern}:{rule.action}, "
                         f"step {current_step()})")
        self.site = site
        self.rule = rule


@dataclasses.dataclass(frozen=True)
class FaultRule:
    pattern: str              # fnmatch glob over site names
    action: str               # "raise" | "nan"
    step: int | None = None   # None: every step
    prob: float = 1.0         # < 1.0: seeded coin flip per match


_RULE = re.compile(
    r"^(?P<pattern>[\w.*?\[\]-]+):(?P<action>\w+)"
    r"(?:@(?:step)?(?P<step>\d+))?"
    r"(?:~p(?P<prob>[0-9.]+))?$")


def parse_fault_spec(spec: str) -> tuple[FaultRule, ...]:
    """Parse a ``fault_spec`` string into rules; raises ValueError on bad
    grammar, unknown actions, or a pattern matching no known site."""
    rules = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        m = _RULE.match(part)
        if m is None:
            raise ValueError(
                f"bad fault rule {part!r}; expected "
                "'<site-glob>:<action>[@stepN][~pP]'")
        action = m.group("action")
        if action not in ACTIONS:
            raise ValueError(
                f"unknown fault action {action!r} in {part!r}; "
                f"actions: {ACTIONS}")
        pattern = m.group("pattern")
        if not any(fnmatch.fnmatchcase(s, pattern) for s in KNOWN_SITES):
            raise ValueError(
                f"fault pattern {pattern!r} matches no known site; sites: "
                f"{sorted(KNOWN_SITES)}")
        prob = float(m.group("prob")) if m.group("prob") else 1.0
        if not 0.0 <= prob <= 1.0:
            raise ValueError(f"fault probability {prob} not in [0, 1]")
        rules.append(FaultRule(
            pattern=pattern, action=action,
            step=int(m.group("step")) if m.group("step") else None,
            prob=prob))
    return tuple(rules)


# -- armed state -------------------------------------------------------------

_ARMED: tuple[FaultRule, ...] | None = None
_RNG = random.Random(0)
_STEP = 0
_FIRED: list[dict] = []
_SEEN: set[str] = set()
_MAX_FIRED = 4096


def arm(spec: str, seed: int = 0) -> tuple[FaultRule, ...]:
    """Arm the injector with ``spec`` (validated); reseeds the probability
    stream so a (spec, seed) pair fires deterministically."""
    global _ARMED, _RNG
    rules = parse_fault_spec(spec)
    _ARMED = rules or None
    _RNG = random.Random(seed)
    return rules


def disarm() -> None:
    global _ARMED
    _ARMED = None


def armed_rules() -> tuple[FaultRule, ...]:
    return _ARMED or ()


def sync_from_config() -> None:
    """(Re-)arm from ``config.fault_spec`` / ``config.fault_seed``; called
    by the config singleton on updates and by this module at import."""
    spec = config.fault_spec
    if spec:
        arm(spec, seed=config.fault_seed)
    else:
        disarm()


def set_step(step: int) -> None:
    """Advance the injection clock; the train loop calls this once per
    step so ``@stepN`` rules target exact steps."""
    global _STEP
    _STEP = int(step)


def current_step() -> int:
    return _STEP


def fired_events() -> list[dict]:
    """Every fault fired since the last :func:`reset_events`."""
    return list(_FIRED)


def seen_sites() -> set[str]:
    """Sites that executed :func:`fault_point` while the injector was
    armed -- CI's coverage assert (arm a never-firing rule, exercise each
    failure domain, compare against :data:`KNOWN_SITES`)."""
    return set(_SEEN)


def reset_events() -> None:
    _FIRED.clear()
    _SEEN.clear()
    # Keep the bus's fault stream in lockstep with _FIRED (no-op when off).
    obs_events.drop("fault")


def _poison(value):
    """NaN-poison every floating leaf of ``value`` (non-float leaves and
    ``None`` pass through untouched)."""
    if value is None:
        return None
    import jax
    import jax.numpy as jnp

    def leaf(a):
        try:
            if jnp.issubdtype(jnp.asarray(a).dtype, jnp.floating):
                return a * jnp.float32(float("nan"))
        except TypeError:
            pass
        return a
    return jax.tree.map(leaf, value)


def fault_point(name: str, value=None):
    """THE fault site: every failure domain calls this with its site name.

    Disarmed (the default): returns ``value`` after one ``is None`` check.
    Armed: the site is recorded as seen, matching ``raise`` rules raise
    :class:`InjectedFault`, and matching ``nan`` rules return a
    NaN-poisoned copy of ``value``.
    """
    if _ARMED is None:
        return value
    if name not in KNOWN_SITES:
        raise ValueError(
            f"unregistered fault site {name!r}; add it to "
            f"repro.ft.inject.KNOWN_SITES (sites: {sorted(KNOWN_SITES)})")
    _SEEN.add(name)
    for rule in _ARMED:
        if not fnmatch.fnmatchcase(name, rule.pattern):
            continue
        if rule.step is not None and rule.step != _STEP:
            continue
        if rule.prob < 1.0 and _RNG.random() >= rule.prob:
            continue
        if len(_FIRED) < _MAX_FIRED:
            _FIRED.append({"site": name, "action": rule.action,
                           "step": _STEP, "pattern": rule.pattern})
            obs_events.emit("fault", name, action=rule.action, step=_STEP,
                            pattern=rule.pattern)
        if rule.action == "raise":
            raise InjectedFault(name, rule)
        value = _poison(value)
    return value


def value_fault_steps(name: str, action: str = "nan") \
        -> tuple[int | None, ...] | None:
    """The steps at which armed ``action`` rules target ``name`` -- or
    None when disarmed / nothing matches.  The jitted train step reads
    this at TRACE time and builds the injection into the graph
    (:func:`nan_factor`), because the step index is a traced value there
    and the Python-side clock cannot see it."""
    if _ARMED is None:
        return None
    _SEEN.add(name)
    steps = tuple(r.step for r in _ARMED
                  if r.action == action
                  and fnmatch.fnmatchcase(name, r.pattern))
    return steps or None


def nan_factor(step, steps: tuple[int | None, ...]):
    """An in-graph multiplier: NaN when the traced ``step`` matches any of
    ``steps`` (``None`` = every step), 1.0 otherwise."""
    import jax.numpy as jnp
    if any(s is None for s in steps):
        return jnp.float32(float("nan"))
    hit = jnp.zeros((), bool)
    for s in steps:
        hit = hit | (jnp.asarray(step, jnp.int32) == s)
    if _FIRED is not None and len(_FIRED) < _MAX_FIRED:
        _FIRED.append({"site": "grad.values", "action": "nan",
                       "step": tuple(int(s) for s in steps),
                       "pattern": "<in-graph>"})
        obs_events.emit("fault", "grad.values", action="nan",
                        step=[int(s) for s in steps], pattern="<in-graph>")
    return jnp.where(hit, jnp.float32(float("nan")), jnp.float32(1.0))


# Adopt any fault spec the config already carries (env var, or an update()
# that ran before this module was imported).
sync_from_config()
