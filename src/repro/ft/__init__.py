from repro.ft.failures import (HeartbeatTable, StragglerDetector, RestartPlan,
                               elastic_mesh, make_restart_plan)
