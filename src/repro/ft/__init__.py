from repro.ft import inject  # noqa: F401  (fault-injection harness)
from repro.ft.failures import (HeartbeatTable, StragglerDetector, RestartPlan,
                               GuardState, elastic_mesh, make_restart_plan,
                               make_guard_restart_plan)
