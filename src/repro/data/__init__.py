from repro.data.pipeline import DataConfig, TokenSource, make_batch, batches
