"""Deterministic, restartable data pipeline.

Production posture without external deps:
  * a synthetic corpus backend (seeded, infinite) and a packed-binary file
    backend (memory-mapped token shards) behind one interface;
  * deterministic sharding: worker w of W reads only batch indices
    ``i * W + w`` -- restart-safe because the batch for global step s is a
    pure function of (seed, s), enabling exact skip-ahead after failure
    (no replayed or skipped samples);
  * per-family batch assembly matching repro.models.model conventions.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    seq_len: int = 512
    global_batch: int = 8
    vocab: int = 256
    worker: int = 0
    n_workers: int = 1
    corpus_path: Optional[str] = None     # packed .npy token shard (optional)


def _rng_for_step(cfg: DataConfig, step: int) -> np.random.Generator:
    # Stable across restarts and independent per step.
    digest = hashlib.sha256(f"{cfg.seed}:{step}".encode()).digest()
    return np.random.default_rng(int.from_bytes(digest[:8], "little"))


class TokenSource:
    """Synthetic or file-backed token stream, step-addressable."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._tokens = None
        if cfg.corpus_path:
            self._tokens = np.load(cfg.corpus_path, mmap_mode="r")

    def batch_tokens(self, step: int, batch: int, seq: int) -> np.ndarray:
        cfg = self.cfg
        if self._tokens is None:
            # Learnable synthetic stream: x[t+1] = x[t] + pattern[t % P]
            # (pattern fixed by the corpus seed), with 10% noise tokens.
            # A model that learns the transition rule reaches low CE fast;
            # the noise floor keeps it non-degenerate.
            pat_rng = np.random.default_rng(cfg.seed)
            pattern = pat_rng.integers(1, 17, size=8)
            rng = _rng_for_step(cfg, step)
            base = rng.integers(0, cfg.vocab, (batch, 1))
            deltas = np.tile(pattern, (batch, (seq + 8) // 8 + 1))[:, :seq]
            toks = (base + np.concatenate(
                [np.zeros((batch, 1), np.int64),
                 np.cumsum(deltas, axis=1)], axis=1)) % cfg.vocab
            noise_mask = rng.random((batch, seq + 1)) < 0.10
            noise = rng.integers(0, cfg.vocab, (batch, seq + 1))
            toks = np.where(noise_mask, noise, toks)
            return toks.astype(np.int32)
        n = self._tokens.shape[0]
        rng = _rng_for_step(cfg, step)
        starts = rng.integers(0, n - seq - 1, (batch,))
        return np.stack([self._tokens[s:s + seq + 1] for s in starts]) \
            .astype(np.int32)


def make_batch(arch: ArchConfig, dcfg: DataConfig, step: int) -> dict:
    """Assemble a host batch (numpy) for this worker's shard of the step."""
    assert dcfg.global_batch % dcfg.n_workers == 0
    local_b = dcfg.global_batch // dcfg.n_workers
    src = TokenSource(dataclasses.replace(dcfg, vocab=arch.vocab))
    rng = _rng_for_step(dcfg, step * 1000003 + dcfg.worker)

    if arch.family == "audio":
        frames = rng.standard_normal(
            (local_b, dcfg.seq_len, arch.d_frontend)).astype(np.float32)
        targets = rng.integers(0, arch.vocab,
                               (local_b, dcfg.seq_len)).astype(np.int32)
        return {"frontend": frames, "targets": targets}

    if arch.family == "vlm":
        f = arch.frontend_tokens
        text_len = dcfg.seq_len - f
        toks = src.batch_tokens(step, local_b, text_len)
        front = rng.standard_normal(
            (local_b, f, arch.d_frontend)).astype(np.float32)
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                "frontend": front}

    toks = src.batch_tokens(step, local_b, dcfg.seq_len)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def batches(arch: ArchConfig, dcfg: DataConfig,
            start_step: int = 0) -> Iterator[dict]:
    """Infinite restartable iterator: resume by passing the restored step."""
    step = start_step
    while True:
        yield make_batch(arch, dcfg, step)
        step += 1
