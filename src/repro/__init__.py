"""repro: BP-im2col implicit conv backprop on systolic arrays (jax/Pallas).

``repro.config`` is the global runtime configuration singleton
(:mod:`repro.core.config`).  It is resolved lazily so that importing
``repro`` submodules stays side-effect free -- in particular,
``repro.launch.dryrun`` must be able to set ``XLA_FLAGS`` before anything
imports jax.
"""


def __getattr__(name):
    if name == "config":
        from repro.core.config import config
        return config
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
