"""phi4-mini-3.8b [dense] — arXiv:2412.08905.

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064; RoPE + SwiGLU + GQA.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=200064,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="phi4-mini-3.8b-smoke",
                     param_dtype="float32", act_dtype="float32")
