"""minicpm-2b [dense] — arXiv:2404.06395 (llama-like; trained with WSD).

40L d_model=2304 36H (kv=36, MHA) d_ff=5760 vocab=122753.  The WSD
(warmup-stable-decay) schedule is provided by repro.optim.schedule and is the
default schedule for this arch in the launcher.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab=122753,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="minicpm-2b-smoke", n_heads=4, n_kv_heads=4,
                     param_dtype="float32", act_dtype="float32")
