"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; RG-LRU recurrent
blocks + local attention, pattern 2 recurrent : 1 attention, window 2048.
Temporal Conv1D (width 4) inside each recurrent block hosts the paper's
BP-im2col conv engine.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    layer_pattern=("rec", "rec", "attn"),
    local_window=2048,
    rglru_conv=4,
    rglru_width=4096,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="recurrentgemma-9b-smoke", rglru_width=64,
                     param_dtype="float32", act_dtype="float32")
