"""internvl2-76b [vlm] — arXiv:2404.16821.

LLM backbone: 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Vision frontend (InternViT-6B) is a STUB per the assignment: input_specs
provides precomputed patch embeddings (d_frontend=3200) which a projector
maps into the LM sequence.  The projector's patch-embedding conv path is the
BP-im2col showcase for stride=patch-size convolutions.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    frontend="vision",
    d_frontend=3200,
    frontend_tokens=256,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="internvl2-76b-smoke",
                     param_dtype="float32", act_dtype="float32")
