"""mamba2-370m [ssm] — arXiv:2405.21060 (SSD, state-space duality).

48L d_model=1024 attn-free vocab=50280, ssm_state=128, expand=2 (d_inner
2048, 32 heads of 64), causal depthwise Conv1D width 4 (hosts BP-im2col).
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,                   # d_inner / ssm_head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="mamba2-370m-smoke", n_heads=4, n_kv_heads=4,
                     d_model=64, ssm_state=16, ssm_head_dim=32,
                     param_dtype="float32", act_dtype="float32")
