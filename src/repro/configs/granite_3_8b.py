"""granite-3-8b [dense] — hf:ibm-granite/granite-3.0-8b-base.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab=49155,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="granite-3-8b-smoke",
                     param_dtype="float32", act_dtype="float32")
