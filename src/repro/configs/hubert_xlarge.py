"""hubert-xlarge [audio] — arXiv:2106.07447 (encoder-only, w2v2 arch).

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.  The waveform conv
feature-encoder frontend is a STUB per the assignment: input_specs provides
precomputed frame embeddings (d_frontend=512).  Encoder-only: bidirectional
attention, no decode shapes.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    attn_kind="bidir",
    frontend="audio",
    d_frontend=512,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="hubert-xlarge-smoke",
                     param_dtype="float32", act_dtype="float32")
