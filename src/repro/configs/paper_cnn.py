"""The paper's own workloads: all stride>=2 conv layers of the evaluated CNNs.

Layer tuples are (H_i, C, N, K, S, P) following Table II's
``H_i(W_i)/C/N/K_h(K_w)/S/P_h(P_w)`` notation.  TABLE2_LAYERS are the five
layers the paper reports cycle counts for; NETWORKS maps each evaluated CNN
to its stride>=2 layers (with multiplicities) for the Fig. 6-8 benchmarks.
Batch size 2 and FP32, matching Section IV's setup.
"""

from __future__ import annotations

from repro.core.im2col_ref import ConvDims

BATCH = 2  # paper Section IV

TABLE2_LAYERS = [
    # (H_i, C, N, K, S, P)        # paper Table II rows
    (224, 3, 64, 3, 2, 0),
    (112, 64, 64, 3, 2, 1),
    (56, 256, 512, 1, 2, 0),
    (28, 244, 244, 3, 2, 1),
    (14, 1024, 2048, 1, 2, 0),
]

# stride>=2 convolutional layers per network (kernel-bearing, from the
# published architectures; depthwise layers carry C==N groups but are modeled
# as standard convs of the same geometry, as the paper's GEMM lowering does).
NETWORKS: dict[str, list[tuple[int, int, int, int, int, int]]] = {
    "alexnet": [
        (224, 3, 64, 11, 4, 2),
    ],
    "densenet": [
        (224, 3, 64, 7, 2, 3),
    ],
    "mobilenet": [
        (224, 3, 32, 3, 2, 1),
        (112, 32, 32, 3, 2, 1),
        (56, 64, 64, 3, 2, 1),
        (28, 128, 128, 3, 2, 1),
        (14, 256, 256, 3, 2, 1),
    ],
    "resnet": [
        (224, 3, 64, 7, 2, 3),
        (56, 256, 512, 1, 2, 0),
        (28, 512, 1024, 1, 2, 0),
        (14, 1024, 2048, 1, 2, 0),
        (56, 128, 128, 3, 2, 1),
        (28, 256, 256, 3, 2, 1),
        (14, 512, 512, 3, 2, 1),
    ],
    "shufflenet": [
        (224, 3, 24, 3, 2, 1),
        (56, 24, 24, 3, 2, 1),
        (28, 116, 116, 3, 2, 1),
        (14, 232, 232, 3, 2, 1),
    ],
    "squeezenet": [
        (224, 3, 96, 7, 2, 0),
    ],
}


def dims(layer: tuple[int, int, int, int, int, int],
         batch: int = BATCH) -> ConvDims:
    h, c, n, k, s, p = layer
    return ConvDims(B=batch, C=c, H_i=h, W_i=h, N=n, K_h=k, K_w=k,
                    S=s, P_h=p, P_w=p)


def table2_dims(batch: int = BATCH) -> list[ConvDims]:
    return [dims(l, batch) for l in TABLE2_LAYERS]
