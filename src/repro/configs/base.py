"""Architecture & shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; reduced smoke
variants derive from the full config via ``.reduced()`` so family-specific
structure (MoE routing, MLA shapes, hybrid patterns, SSM state) is preserved
while widths shrink to CPU scale.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # attention
    attn_kind: str = "causal"         # causal | bidir (encoder-only)
    local_window: Optional[int] = None
    layer_pattern: Optional[tuple[str, ...]] = None   # hybrid: e.g. ("rec","rec","attn")
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0       # deepseek-v3: first k layers stay dense
    capacity_factor: float = 1.25
    # MLA (deepseek-v3)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp_depth: int = 0                # multi-token-prediction extra blocks
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    # recurrent (RG-LRU / Griffin)
    rglru_conv: int = 4
    rglru_width: int = 0              # recurrent block width (defaults d_model)
    # modality frontend stubs
    frontend: Optional[str] = None    # vision | audio
    d_frontend: int = 0
    frontend_tokens: int = 0
    # numerics & engineering knobs
    param_dtype: str = "float32"
    act_dtype: str = "float32"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Per-pass conv backprop engine selection (the paper): an EnginePolicy
    # string -- "auto", a uniform engine name, or
    # "fwd=...,dgrad=...,wgrad=..." (repro.core.EnginePolicy.parse).
    conv_policy: str = "auto"
    # DEPRECATED: the old uniform engine knob.  When set it wins over
    # conv_policy (mapped to a uniform EnginePolicy) with a warning.
    conv_mode: Optional[str] = None
    attn_impl: str = "xla"            # xla | flash (Pallas kernel)
    remat: str = "block"              # none | block

    # ------------------------------------------------------------------
    @property
    def conv_engine_policy(self) -> str:
        """The effective conv EnginePolicy string: ``conv_mode`` (deprecated,
        uniform) when set, else ``conv_policy``.  Model code reads this."""
        if self.conv_mode is not None:
            warnings.warn(
                "ArchConfig.conv_mode is deprecated; set conv_policy "
                "(e.g. conv_policy=\"fwd=pallas,dgrad=auto,wgrad=bp_phase\" "
                "or a uniform engine name) instead",
                DeprecationWarning, stacklevel=2)
            return self.conv_mode
        return self.conv_policy

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.act_dtype)

    @property
    def is_encoder_only(self) -> bool:
        return self.attn_kind == "bidir"

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic archs only: SSM + hybrid (local attention window)."""
        return self.family in ("ssm", "hybrid")

    def layer_kind(self, i: int) -> str:
        """'attn' | 'rec' | 'ssm' for block i."""
        if self.family == "ssm":
            return "ssm"
        if self.layer_pattern:
            return self.layer_pattern[i % len(self.layer_pattern)]
        return "attn"

    def is_moe_layer(self, i: int) -> bool:
        return self.n_experts > 0 and i >= self.first_dense_layers

    def reduced(self, **overrides) -> "ArchConfig":
        """CPU-scale variant preserving family structure."""
        base = dict(
            n_layers=min(self.n_layers, 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=16,
            d_ff=128,
            vocab=256,
        )
        if self.n_experts:
            base.update(n_experts=8, moe_top_k=min(2, self.moe_top_k),
                        n_shared_experts=min(1, self.n_shared_experts),
                        moe_d_ff=64, first_dense_layers=min(1, self.first_dense_layers))
        if self.use_mla:
            base.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                        qk_rope_head_dim=8, v_head_dim=16, head_dim=24)
        if self.ssm_state:
            base.update(ssm_state=16, ssm_head_dim=16)
        if self.local_window:
            base.update(local_window=32)
        if self.frontend:
            base.update(d_frontend=32, frontend_tokens=8)
        if self.mtp_depth:
            base.update(mtp_depth=1)
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode | long_decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "long_decode"),
}


def applicable_shapes(cfg: ArchConfig) -> list[str]:
    """The (arch x shape) matrix rules from the assignment:
    - encoder-only archs have no decode step -> skip decode/long shapes;
    - long_500k requires sub-quadratic attention -> SSM / hybrid only.
    """
    out = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder_only:
        out.append("decode_32k")
        if cfg.supports_long_context:
            out.append("long_500k")
    return out
