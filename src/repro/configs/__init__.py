"""Config registry: ``get_config(name)`` / ``get_smoke_config(name)``.

One module per assigned architecture (exact published dims) plus the paper's
own CNN workloads (``paper_cnn``).
"""

from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeCfg, SHAPES, applicable_shapes

ARCH_IDS = [
    "deepseek_v3_671b",
    "moonshot_v1_16b_a3b",
    "recurrentgemma_9b",
    "internvl2_76b",
    "smollm_360m",
    "phi4_mini_3_8b",
    "minicpm_2b",
    "granite_3_8b",
    "hubert_xlarge",
    "mamba2_370m",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({
    "deepseek-v3-671b": "deepseek_v3_671b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "internvl2-76b": "internvl2_76b",
    "smollm-360m": "smollm_360m",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "minicpm-2b": "minicpm_2b",
    "granite-3-8b": "granite_3_8b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-370m": "mamba2_370m",
})


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.FULL


def get_smoke_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE


def all_arch_ids() -> list[str]:
    return list(ARCH_IDS)
