"""smollm-360m [dense] — hf:HuggingFaceTB/SmolLM-360M (llama arch).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab=49152,
    tie_embeddings=True,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="smollm-360m-smoke", n_heads=3, n_kv_heads=1,
                     param_dtype="float32", act_dtype="float32")
