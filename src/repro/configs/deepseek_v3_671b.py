"""deepseek-v3-671b [moe] — arXiv:2412.19437 / hf:deepseek-ai/DeepSeek-V3.

61L d_model=7168 128H d_ff(moe expert)=2048 vocab=129280, MoE 1 shared + 256
routed top-8, MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128), MTP.
First 3 layers dense (d_ff 18432).  The assignment's "d_ff=2048" is the
routed-expert hidden dim; the dense layers use the published 18432.
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,                 # qk = nope 128 + rope 64
    d_ff=18432,                   # dense layers
    vocab=129280,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_dense_layers=3,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    mtp_depth=1,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="deepseek-v3-671b-smoke",
                     param_dtype="float32", act_dtype="float32")
