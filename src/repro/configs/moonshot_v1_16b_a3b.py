"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B.

48L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=163840, 64 routed experts
top-6 + 2 shared (per the HF config), first layer dense (d_ff 11264).
"""

from repro.configs.base import ArchConfig

FULL = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=11264,                   # dense first layer
    vocab=163840,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    param_dtype="bfloat16",
    act_dtype="bfloat16",
)

SMOKE = FULL.reduced(name="moonshot-v1-16b-a3b-smoke",
                     param_dtype="float32", act_dtype="float32")
