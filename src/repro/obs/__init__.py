"""Unified telemetry: event bus + span tracer + metrics stream.

One subsystem behind three ``repro.config`` fields:

====================  =====================  ==================================
field                 env alias              effect
====================  =====================  ==================================
``telemetry``         ``REPRO_TELEMETRY``    master switch; off (default) is
                                             the zero-overhead disarmed path
``trace_path``        ``REPRO_TRACE_PATH``   Perfetto trace_event JSON output
``metrics_path``      ``REPRO_METRICS_PATH`` per-step metrics JSONL output
====================  =====================  ==================================

Usage::

    from repro.core.config import config
    from repro import obs

    config.update(telemetry=True, trace_path="out.json",
                  metrics_path="m.jsonl")
    ...                        # run: dispatch/plan/fault/serve events flow
    report = obs.finalize()    # writes the trace, closes the stream
    assert not report["divergences"]

The legacy introspection surfaces (``conv.dispatch_events()``,
``ops.plan_events()``, ``inject.fired_events()`` ...) are unchanged and
remain the source of truth; with telemetry on, the same chokepoints also
emit to the bus, and :func:`report` cross-checks that every legacy
counter agrees with its bus-backed view (``events.counters(kind)``).
"""

from __future__ import annotations

import sys

from repro.obs import events, metrics, trace

__all__ = ["events", "metrics", "trace", "enabled", "sync_from_config",
           "reset_all", "report", "finalize"]


def enabled() -> bool:
    """True when the event bus is recording (``config.telemetry``)."""
    return events.enabled()


def sync_from_config() -> None:
    """Re-sync all three subsystems from ``repro.config`` (called by
    ``config.update``/``override`` whenever a telemetry field changes)."""
    events.sync_from_config()
    trace.sync_from_config()
    metrics.sync_from_config()


#: every legacy reset_* surface, reachable lazily (module -> functions).
#: sys.modules.get keeps reset_all free of heavy imports: a module that
#: was never imported has nothing to reset.
_RESET_SURFACES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("repro.core.conv", ("reset_dispatch_events", "clear_quarantine")),
    ("repro.kernels.ops", ("reset_plan_events",)),
    ("repro.ft.inject", ("reset_events",)),
    ("repro.ckpt.checkpoint", ("reset_skipped_checkpoints",)),
)


def reset_all() -> None:
    """One reset covering every introspection surface in the repo: the
    legacy counters (dispatch/plan/fault/quarantine/checkpoint) and the
    obs bus/trace/metrics window.  Used by the test suite's autouse
    fixture; deliberately does NOT clear the tile-plan or autotune caches
    (those are plan state, not introspection state)."""
    for mod_name, fns in _RESET_SURFACES:
        mod = sys.modules.get(mod_name)
        if mod is not None:
            for fn in fns:
                getattr(mod, fn)()
    events.reset()
    trace.reset()
    metrics.reset_window()


def _diff_counters(legacy: dict, view: dict) -> list[str]:
    problems = []
    for name in sorted(set(legacy) | set(view)):
        if legacy.get(name, 0) != view.get(name, 0):
            problems.append(
                f"{name}: legacy={legacy.get(name, 0)} "
                f"bus={view.get(name, 0)}")
    return problems


def report() -> dict:
    """End-of-run summary: event totals by kind, trace/metrics shape, the
    legacy counters, and -- the CI gate -- any divergence between a legacy
    counter dict and its bus-backed view.  Divergences are only meaningful
    while telemetry is on and the bus has not saturated."""
    conv = sys.modules.get("repro.core.conv")
    ops = sys.modules.get("repro.kernels.ops")
    inject = sys.modules.get("repro.ft.inject")

    divergences: list[str] = []
    if events.enabled() and events.dropped() == 0:
        if conv is not None:
            for p in _diff_counters(conv.dispatch_events(),
                                    events.counters("dispatch")):
                divergences.append(f"dispatch:{p}")
        if ops is not None:
            for p in _diff_counters(ops.plan_events(),
                                    events.counters("plan")):
                divergences.append(f"plan:{p}")
        if inject is not None:
            fired = inject.fired_events()
            n_bus = len(events.events("fault"))
            if len(fired) != n_bus:
                divergences.append(
                    f"fault: legacy fired={len(fired)} bus={n_bus}")

    by_kind = {k: len(events.events(k)) for k in events.KINDS}
    return {
        "telemetry": events.enabled(),
        "events_total": sum(by_kind.values()),
        "events_by_kind": by_kind,
        "events_dropped": events.dropped(),
        "divergences": divergences,
        "consistent": not divergences,
        "trace": trace.summary(),
        "metrics": metrics.summary(),
        "legacy": {
            "dispatch": dict(conv.dispatch_events()) if conv else {},
            "plan": dict(ops.plan_events()) if ops else {},
            "faults_fired": len(inject.fired_events()) if inject else 0,
        },
    }


def finalize() -> dict:
    """End of run: export the trace file (if configured), close the
    metrics stream, and return :func:`report`."""
    rep = report()
    rep["trace_file"] = trace.export()
    metrics.close()
    return rep


sync_from_config()
