"""Span tracer emitting Chrome/Perfetto ``trace_event`` JSON.

Spans wrap the hot structural moments of a run -- conv dispatch per
pass/engine (annotated with the ConvDims geometry, ``taps{real,
materialized}``, ``skip_ratio`` and modeled ``bytes_moved``), autotune
candidate timing, mesh halo ``ppermute`` exchanges, checkpoint writes and
serve prefill/insert/decode steps -- and export as a single
``{"traceEvents": [...]}`` file that chrome://tracing and ui.perfetto.dev
load directly.  Each span also passes through
``jax.profiler.TraceAnnotation`` so the same names line up inside XLA
device profiles.

Disarmed idiom (``ft/inject.py`` contract): the buffer global is ``None``
when tracing is off and :func:`span` returns one shared pre-allocated
null context manager -- no per-call allocation, no timestamping.

Events use the Duration form: paired ``"ph": "B"`` / ``"ph": "E"`` records
per (pid, tid) with microsecond ``ts`` from ``perf_counter``, so nesting
is positional and ``scripts/validate_trace.py`` can check balance.
Because conv dispatch happens at jax TRACE time, conv spans measure
trace/compile-side dispatch, not steady-state device time -- which is
exactly where the degradation ladder and plan lookups live.
"""

from __future__ import annotations

import json
import os
import threading
import time

MAX_TRACE_EVENTS = 200_000

_BUF: list[dict] | None = None    # None == tracing off (disarmed idiom)
_DROPPED = 0
_PID = os.getpid()

_JAX_ANNOTATION = None            # resolved lazily on first span


class _NullSpan:
    """The shared do-nothing context manager returned when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


def _annotation_cls():
    """``jax.profiler.TraceAnnotation`` resolved lazily, so importing
    repro.obs never forces jax in (dryrun sets XLA_FLAGS pre-import)."""
    global _JAX_ANNOTATION
    if _JAX_ANNOTATION is None:
        try:
            from jax.profiler import TraceAnnotation
            _JAX_ANNOTATION = TraceAnnotation
        except Exception:               # pragma: no cover - jax always here
            _JAX_ANNOTATION = False
    return _JAX_ANNOTATION


class _Span:
    __slots__ = ("name", "args", "_ann")

    def __init__(self, name: str, args: dict):
        self.name = name
        self.args = args

    def __enter__(self):
        global _DROPPED
        buf = _BUF
        if buf is not None:
            if len(buf) < MAX_TRACE_EVENTS:
                buf.append({"ph": "B", "name": self.name, "pid": _PID,
                            "tid": threading.get_ident(),
                            "ts": time.perf_counter() * 1e6,
                            "args": self.args})
            else:
                _DROPPED += 1
        ann = _annotation_cls()
        self._ann = ann(self.name) if ann else None
        if self._ann is not None:
            self._ann.__enter__()
        return self

    def __exit__(self, *exc):
        global _DROPPED
        if self._ann is not None:
            self._ann.__exit__(*exc)
        buf = _BUF
        if buf is not None:
            if len(buf) < MAX_TRACE_EVENTS:
                buf.append({"ph": "E", "name": self.name, "pid": _PID,
                            "tid": threading.get_ident(),
                            "ts": time.perf_counter() * 1e6})
            else:
                _DROPPED += 1
        return False


def active() -> bool:
    """True when spans are being recorded."""
    return _BUF is not None


def span(name: str, **args):
    """A context manager recording one B/E span pair.  When tracing is off
    this returns the shared null singleton (a single ``is None`` check)."""
    if _BUF is None:
        return _NULL
    return _Span(name, args)


def conv_annotations(d, transposed: bool = False) -> dict:
    """The paper-facing annotation dict for one conv dispatch: geometry,
    real-vs-materialized taps, zero-space ``skip_ratio`` and the modeled
    compact-layout traffic ``bytes_moved`` (f32 activations + compact
    weights + outputs -- what roofline.py calls the implicit-im2col
    traffic, NOT a measured number)."""
    real = d.k_taps_h * d.k_taps_w
    if transposed:
        # Mirror-conv identity: the materialized alternative zero-inserts
        # stride phases too, so the denominator is s*K per axis.
        materialized = (d.s_h * d.K_h) * (d.s_w * d.K_w)
    else:
        materialized = d.K_h * d.K_w
    itemsize = 4
    elems = (d.B * d.C * d.H_i * d.W_i          # source activations
             + d.N * d.C * real                 # compact weight taps
             + d.B * d.N * d.H_o * d.W_o)       # outputs
    return {
        "dims": {"B": d.B, "C": d.C, "H_i": d.H_i, "W_i": d.W_i,
                 "N": d.N, "K_h": d.K_h, "K_w": d.K_w,
                 "s_h": d.s_h, "s_w": d.s_w, "D_h": d.D_h, "D_w": d.D_w},
        "taps": {"real": real, "materialized": materialized},
        "skip_ratio": round(1.0 - real / materialized, 6),
        "bytes_moved": elems * itemsize,
    }


def dispatch_span(pkey: str, engine: str, d):
    """Span around one conv engine execution (``core/conv.py _execute``).
    ``pkey`` is the dispatch pass key (``fwd``/``dgrad``/``wgrad`` with an
    ``_T`` suffix for transposed convs)."""
    if _BUF is None:
        return _NULL
    args = {"pass": pkey, "engine": engine}
    args.update(conv_annotations(d, transposed=pkey.endswith("_T")))
    return _Span(f"conv:{pkey}:{engine}", args)


def dropped() -> int:
    return _DROPPED


def summary() -> dict:
    """Shape of the recorded trace (for ``obs.report()``)."""
    buf = _BUF if _BUF is not None else []
    names: dict[str, int] = {}
    for e in buf:
        if e["ph"] == "B":
            key = e["name"].split(":", 1)[0]
            names[key] = names.get(key, 0) + 1
    return {"active": _BUF is not None, "events": len(buf),
            "spans_by_prefix": names, "dropped": _DROPPED}


def export(path: str | None = None) -> str | None:
    """Write the Chrome/Perfetto ``trace_event`` JSON.  ``path`` defaults
    to ``config.trace_path``; returns the path written, or None when
    tracing is off / no path is configured."""
    if _BUF is None:
        return None
    if path is None:
        from repro.core.config import config
        path = config.trace_path
    if path is None:
        return None
    doc = {"traceEvents": list(_BUF),
           "displayTimeUnit": "ms",
           "otherData": {"producer": "repro.obs.trace",
                         "dropped_events": _DROPPED}}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)
    return path


def reset() -> None:
    """Clear the buffer; keeps the active/inactive state."""
    global _BUF, _DROPPED
    if _BUF is not None:
        _BUF = []
    _DROPPED = 0


def sync_from_config() -> None:
    """Tracing is active iff ``telemetry`` is on AND a ``trace_path`` is
    set (spans exist to be exported; the bus alone needs no buffer)."""
    global _BUF
    from repro.core.config import config
    if config.telemetry and config.trace_path:
        if _BUF is None:
            _BUF = []
    else:
        _BUF = None


sync_from_config()
