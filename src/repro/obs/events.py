"""The structured event bus: every scattered counter, one stream.

The repo grew seven disconnected introspection surfaces (conv
``dispatch_events``, kernel ``plan_events``, policy decisions, runtime
failures, ``inject.fired_events``, serve counters, guard metrics).  This
module is the single bus those surfaces re-register onto: each legacy
recording chokepoint (``conv._record_event``, ``ops._count_event``,
``inject.fault_point`` ...) ALSO calls :func:`emit` here, so a live run
sees one ordered, timestamped, tagged stream -- while the legacy dicts
stay untouched as the source of truth and keep behaving byte-identically
when telemetry is off.

Zero-overhead disarmed idiom (the ``ft/inject.py`` contract): the sink is
a module global that is ``None`` when ``config.telemetry`` is off, and
every :func:`emit` call starts with ``if _SINK is None: return``.  No
allocation, no timestamping, no dict building on the disabled path.

Consistency contract: the legacy ``reset_*`` functions call
:func:`drop` for their kind (a no-op when disabled), so the bus-backed
views (:func:`counters`) can never desync from the legacy dicts under
any reset pattern.  ``repro.obs.report()`` checks this invariant.
"""

from __future__ import annotations

import time

#: registered event kinds -> (emitting module, description).  ``emit`` with
#: an unregistered kind raises (when enabled), and
#: ``scripts/check_obs_events.py`` machine-checks docs/OBSERVABILITY.md
#: against this registry.
KINDS: dict[str, tuple[str, str]] = {
    "dispatch": (
        "core/conv.py, dist/conv_parallel.py",
        "engine dispatch, degradation, quarantine/probe/recover, and "
        "mesh lowering drops/fallbacks (the dispatch_events names)"),
    "plan": (
        "kernels/ops.py, kernels/autotune.py",
        "tile-plan outcomes per role (pallas/fallback) and autotune "
        "hit/miss/stale/poisoned/measure_failed"),
    "fault": (
        "ft/inject.py",
        "every injected fault that fired (site, action, step, pattern)"),
    "halo": (
        "dist/conv_parallel.py",
        "per-exchange mesh halo ppermute traffic with modeled byte counts"),
    "serve": (
        "serve/engine.py, serve/continuous.py",
        "request lane lifecycle: admit, insert, wave, finalize with "
        "per-request latency"),
    "ckpt": (
        "ckpt/checkpoint.py",
        "checkpoint writes/restores (step, path, skipped)"),
    "train": (
        "launch/train.py, examples/",
        "training-loop level events (guard trips, rollbacks)"),
}

#: hard cap on buffered events; beyond it new events are counted as
#: dropped, never silently lost (report() surfaces the number).
MAX_EVENTS = 65536

_SINK: list[dict] | None = None   # None == telemetry off (disarmed idiom)
_DROPPED = 0
_SEQ = 0


def enabled() -> bool:
    """True when the bus is recording (``config.telemetry`` is on)."""
    return _SINK is not None


def emit(kind: str, name: str, **tags) -> None:
    """Record one event.  Free (a single ``is None`` check) when off."""
    global _SEQ, _DROPPED
    if _SINK is None:
        return
    if kind not in KINDS:
        raise ValueError(
            f"unregistered event kind {kind!r}; known kinds: {tuple(KINDS)}")
    if len(_SINK) >= MAX_EVENTS:
        _DROPPED += 1
        return
    _SEQ += 1
    _SINK.append({"seq": _SEQ, "ts": time.time(), "kind": kind,
                  "name": name, "tags": tags})


def events(kind: str | None = None) -> list[dict]:
    """The recorded events (optionally filtered by kind), oldest first."""
    if _SINK is None:
        return []
    if kind is None:
        return list(_SINK)
    return [e for e in _SINK if e["kind"] == kind]


def counters(kind: str) -> dict[str, int]:
    """Bus-backed counter view: event name -> occurrence count.

    For ``kind="dispatch"`` / ``"plan"`` this is exactly the shape of the
    legacy ``conv.dispatch_events()`` / ``ops.plan_events()`` dicts, and
    ``repro.obs.report()`` asserts they agree.
    """
    out: dict[str, int] = {}
    if _SINK is not None:
        for e in _SINK:
            if e["kind"] == kind:
                out[e["name"]] = out.get(e["name"], 0) + 1
    return out


def dropped() -> int:
    """Events discarded because the buffer hit :data:`MAX_EVENTS`."""
    return _DROPPED


def drop(kind: str) -> None:
    """Discard all events of one kind.  Called by the legacy ``reset_*``
    functions (no-op when disabled) so bus views track legacy resets."""
    global _SINK
    if _SINK is not None:
        _SINK = [e for e in _SINK if e["kind"] != kind]


def reset() -> None:
    """Clear the bus (buffer, sequence and dropped count); keeps the
    enabled/disabled state."""
    global _SINK, _DROPPED, _SEQ
    if _SINK is not None:
        _SINK = []
    _DROPPED = 0
    _SEQ = 0


def sync_from_config() -> None:
    """(Re-)arm from ``repro.config``: telemetry on installs a sink if none
    is active; telemetry off drops it (back to the zero-overhead path)."""
    global _SINK
    from repro.core.config import config
    if config.telemetry:
        if _SINK is None:
            _SINK = []
    else:
        _SINK = None


sync_from_config()
