"""Per-step metrics stream: one JSON line per training step / serve tick.

Active iff ``config.telemetry`` is on AND ``config.metrics_path`` is set;
every emitter starts with the ``if _STREAM is None: return`` disarmed
check (the ``ft/inject.py`` idiom), so a run without telemetry pays one
attribute read per step.

Line shapes (all lines carry ``ts`` and ``kind``):

``kind="train_step"`` -- per optimizer step: ``step``, ``loss``,
    ``grad_norm``, guard state (``guard_bad``/``guard_streak``/
    ``guard_clipped``), wall ``step_s``, the conv ``dispatch_mix``
    (engine -> dispatch count so far) and the tile-plan-cache hit rate.

``kind="serve_tick"`` -- per decode tick of either serving engine:
    ``engine``, ``decode_steps``, ``tokens``, lane ``occupancy``,
    ``decode_tok_s``, latency ``p50_s``/``p99_s`` over finalized
    requests, ``timed_out``/``failed`` counts.

The file is JSONL, flushed per line, so a crashed run keeps everything
emitted before the crash.
"""

from __future__ import annotations

import json
import sys
import time

MAX_LATENCIES = 100_000

_STREAM = None                 # open file object, or None == off
_PATH: str | None = None
_LINES = 0
_LATENCIES: list[float] = []   # finalized-request latencies (seconds)


def active() -> bool:
    return _STREAM is not None


def emit(kind: str, **payload) -> None:
    """Write one metrics line.  Free (one ``is None`` check) when off."""
    global _LINES
    if _STREAM is None:
        return
    line = {"ts": time.time(), "kind": kind}
    line.update(payload)
    _STREAM.write(json.dumps(line) + "\n")
    _STREAM.flush()
    _LINES += 1


def train_step(step: int, metrics: dict, *, step_s: float | None = None,
               **extra) -> None:
    """Per-training-step line.  ``metrics`` is the train-step metrics dict
    (loss / grad_norm / lr / guard_*); dispatch mix and plan-cache hit
    rate are sampled from the live counters (lazy through sys.modules --
    emitting metrics must not force the kernel stack in)."""
    if _STREAM is None:
        return
    payload: dict = {"step": int(step)}
    for key in ("loss", "grad_norm", "lr", "guard_bad", "guard_streak",
                "guard_clipped"):
        if key in metrics:
            payload[key] = float(metrics[key])
    if step_s is not None:
        payload["step_s"] = round(float(step_s), 6)
    conv = sys.modules.get("repro.core.conv")
    if conv is not None:
        mix: dict[str, int] = {}
        for name, n in conv.dispatch_events().items():
            parts = name.split(":")
            if len(parts) == 2 and "->" not in parts[1]:
                mix[parts[1]] = mix.get(parts[1], 0) + n
        payload["dispatch_mix"] = mix
    ops = sys.modules.get("repro.kernels.ops")
    if ops is not None:
        info = ops.tile_plan_cache_info()
        hits = sum(ci.hits for ci in info.values())
        misses = sum(ci.misses for ci in info.values())
        payload["plan_cache_hit_rate"] = (
            round(hits / (hits + misses), 4) if hits + misses else None)
    payload.update(extra)
    emit("train_step", **payload)


def record_latency(latency_s: float) -> None:
    """Register one finalized request latency for the serve percentiles."""
    if _STREAM is None:
        return
    if len(_LATENCIES) < MAX_LATENCIES:
        _LATENCIES.append(latency_s)


def _percentile(sorted_vals: list[float], q: float) -> float | None:
    if not sorted_vals:
        return None
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return round(sorted_vals[idx], 6)


def serve_tick(engine) -> None:
    """Per-decode-tick line for either serving engine (they share the
    counters/stats vocabulary, see ``serve/engine.py run_summary``)."""
    if _STREAM is None:
        return
    c, st = engine.counters, engine.stats
    decode_steps = c.get("decode_steps", 0)
    lane_steps = st.get("lane_steps", 0)
    occupancy = (lane_steps / (decode_steps * engine.max_batch)
                 if decode_steps else 0.0)
    decode_s = st.get("decode_s", 0.0)
    lat = sorted(_LATENCIES)
    emit("serve_tick",
         engine=getattr(engine, "engine_kind", "?"),
         decode_steps=decode_steps,
         tokens=st.get("tokens", 0),
         occupancy=round(occupancy, 4),
         decode_tok_s=round(st.get("tokens", 0) / decode_s, 2)
         if decode_s else None,
         p50_s=_percentile(lat, 0.50),
         p99_s=_percentile(lat, 0.99),
         completed=c.get("completed", 0),
         timed_out=c.get("timed_out", 0),
         failed=c.get("failed", 0))


def lines_written() -> int:
    return _LINES


def summary() -> dict:
    return {"active": _STREAM is not None, "path": _PATH, "lines": _LINES,
            "latencies": len(_LATENCIES)}


def reset_window() -> None:
    """Clear the in-memory aggregation window (latencies).  Does not touch
    the output file."""
    _LATENCIES.clear()


def close() -> None:
    global _STREAM, _PATH
    if _STREAM is not None:
        _STREAM.close()
        _STREAM = None
        _PATH = None


def sync_from_config() -> None:
    """Open/close/rotate the JSONL stream to match the config."""
    global _STREAM, _PATH, _LINES
    from repro.core.config import config
    want = config.metrics_path if config.telemetry else None
    if want == _PATH and (want is None) == (_STREAM is None):
        return
    close()
    if want is not None:
        _STREAM = open(want, "w")
        _PATH = want
        _LINES = 0
        _LATENCIES.clear()


sync_from_config()
