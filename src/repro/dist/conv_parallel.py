"""Mesh-parallel conv lowerings: ``shard_map`` + tap-derived halo exchange.

The tap-GEMM engines are single-device programs; this module makes them run
*sharded* without touching them.  A :class:`ConvParallel` policy names which
mesh axes shard which conv role -- batch, spatial H/W, Cin, Cout -- and
:func:`conv_mesh` installs a lowering hook on ``repro.core.conv`` that
intercepts every ``conv2d`` / ``conv2d_transpose`` in its dynamic extent.
Intercepted calls that pass :func:`plan_conv_sharding`'s divisibility and
geometry checks lower onto explicit per-pass ``shard_map`` bodies (wrapped in
their own ``custom_vjp``), everything else falls back to the single-device
custom_vjp with the reason recorded in ``dispatch_events`` /
``policy_decisions`` -- parallelism is a policy, never a crash.

Spatial sharding exchanges exactly the planner's tap-derived halos
(:func:`repro.kernels.ops.shard_halo`): ``lo = P_lo`` and
``hi = span - s - P_lo`` rows/cols per boundary, where ``span`` is the extent
of the KEPT kernel taps.  Dilation zeros are dropped from the tap table at
plan time, so no zero-space ever crosses the wire -- the paper's bandwidth
argument applied to the collective fabric.  ``ppermute`` destinations that
name nobody receive zeros, so edge shards get exactly the zero rows the
global padding would have provided: the halo exchange *is* the padding.

Reduction placement per pass (EcoFlow's observation that fwd/dgrad/wgrad
reduce over different axes):

    ==============  ===============  ===============  ==================
    pass            regular conv     transposed conv  psum axis
    ==============  ===============  ===============  ==================
    forward         contracts Cin    contracts Cin    ``cin`` shards
    input grad      contracts Cout   contracts Cout   ``cout`` shards
    weight grad     contracts B,H,W  contracts B,H,W  ``batch`` + spatial
    ==============  ===============  ===============  ==================

Transposed convs ride the mirror-conv identity end to end: the mirror input
plane (= the transposed layer's OUTPUT) is the halo-exchanged plane; the
transposed forward scatter-adds halo contributions (the transpose of the
regular gather), the transposed input grad gathers them.
"""

from __future__ import annotations

import contextlib
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

import math

from repro.core import conv as C
from repro.core.convspec import ConvSpec, ConvTransposeSpec
from repro.kernels.ops import shard_halo
from repro.dist.constraints import _active_mesh
from repro.obs import events as obs_events
from repro.obs import trace as obs_trace

#: conv-role names a plan can shard (event tags join them with "+").
ROLES = ("data", "h", "w", "cin", "cout")


def _mesh_axes(mesh) -> dict:
    return dict(mesh.shape)


def _size(mesh, axes) -> int:
    if not axes:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    shape = _mesh_axes(mesh)
    total = 1
    for a in axes:
        total *= shape.get(a, 1)
    return total


# ---------------------------------------------------------------------------
# Policy: which mesh axes shard which conv role
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvParallel:
    """Mesh-axis assignment per conv role.

    ``batch`` is a tuple of axis names carrying the batch dim; ``h``/``w``
    spatially partition the activation planes with halo exchange;
    ``cin``/``cout`` partition the channel contractions.  Hashable (rides
    inside the custom_vjp's nondiff plan argument).
    """

    batch: tuple[str, ...] = ()
    h: str | None = None
    w: str | None = None
    cin: str | None = None
    cout: str | None = None

    @classmethod
    def from_policy(cls, policy, mesh) -> "ConvParallel":
        """Resolve a ``dist.sharding`` policy name against a concrete mesh.

        ``tp``      -- batch over ("pod", "data"); Cout over "model" (the
                       conv analogue of the linear d_out="model" rule; Cin
                       stays replicated so it cannot collide with the batch
                       axes).
        ``dp_only`` -- pure data parallelism: batch over every axis.
        ``tp_rep``  -- batch over ("pod", "data"), params replicated.
        ``spatial`` -- batch over ("pod", "data"); H over "model" with halo
                       exchange (activation-heavy layers where channel
                       sharding starves the MXU).
        """
        if isinstance(policy, cls):
            return policy
        names = tuple(_mesh_axes(mesh))
        dp = tuple(a for a in ("pod", "data") if a in names)
        if policy == "dp_only":
            return cls(batch=tuple(a for a in ("pod", "data", "model")
                                   if a in names))
        if policy in ("tp", "tensor_parallel"):
            return cls(batch=dp, cout="model" if "model" in names else None)
        if policy == "tp_rep":
            return cls(batch=dp)
        if policy == "spatial":
            return cls(batch=dp, h="model" if "model" in names else None)
        raise ValueError(
            f"unknown conv mesh policy {policy!r}; expected a ConvParallel "
            f"or one of 'tp', 'dp_only', 'tp_rep', 'spatial'")

    @classmethod
    def coerce(cls, value, mesh) -> "ConvParallel":
        if isinstance(value, cls):
            return value
        return cls.from_policy(value, mesh)


# ---------------------------------------------------------------------------
# Plan: the checked, per-layer shard assignment
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvShardPlan:
    """One conv layer's mesh assignment after every divisibility / geometry
    check: the roles that survived, the tap-derived halos for the spatial
    ones, and the roles that were dropped with WHY (surfaced through
    ``dispatch_events`` / ``policy_decisions`` by the lowering hook)."""

    mesh: object
    batch: tuple[str, ...] = ()
    h: str | None = None
    w: str | None = None
    cin: str | None = None
    cout: str | None = None
    halo_h: tuple[int, int] = (0, 0)
    halo_w: tuple[int, int] = (0, 0)
    transposed: bool = False
    dropped: tuple[tuple[str, str], ...] = ()

    @property
    def roles(self) -> tuple[str, ...]:
        out = []
        if self.batch:
            out.append("data")
        for role in ("h", "w", "cin", "cout"):
            if getattr(self, role):
                out.append(role)
        return tuple(out)

    @property
    def tag(self) -> str:
        return "+".join(self.roles) or "replicated"

    def size(self, axes) -> int:
        return _size(self.mesh, axes)

    @property
    def batch_spec(self):
        if not self.batch:
            return None
        return self.batch if len(self.batch) > 1 else self.batch[0]


def _check_spatial(name: str, n: int, h_i: int, h_o: int, s: int,
                   lo: int, hi: int) -> str | None:
    """None if an input plane of ``h_i`` rows (output ``h_o``) can be cut
    into ``n`` uniform blocks whose stride windows tile exactly, else the
    reason it cannot."""
    if h_i % n:
        return f"{name}: input extent {h_i} % {n} shards != 0"
    if h_o % n:
        return f"{name}: output extent {h_o} % {n} shards != 0"
    if h_i != s * h_o:
        return (f"{name}: non-uniform geometry (input {h_i} != stride {s} x "
                f"output {h_o}); spatial sharding needs SAME-style padding")
    blk = h_i // n
    if lo > blk or hi > blk:
        return (f"{name}: halo ({lo}, {hi}) exceeds the {blk}-row shard "
                f"block (single-hop exchange)")
    return None


def plan_conv_sharding(x_shape, w_shape, spec, par: ConvParallel,
                       mesh) -> ConvShardPlan:
    """Validate a :class:`ConvParallel` request against one layer's geometry.

    Degrades per role, never whole-or-nothing: an indivisible batch drops
    only the batch sharding, a non-uniform plane drops only that spatial
    axis, a grouped conv drops only the channel roles -- each with a
    recorded reason.  Size-1 / absent-from-the-mesh axes are dropped
    silently (sharding over them is the identity).  ``mesh`` only needs a
    ``.shape`` mapping, so plans are testable without devices.
    """
    transposed = isinstance(spec, ConvTransposeSpec)
    d = (C.transpose_dims if transposed else C.spec_dims)(
        x_shape, w_shape, spec)
    shape = _mesh_axes(mesh)
    dropped: list[tuple[str, str]] = []
    used: set[str] = set()

    def usable(role: str, axes) -> tuple[str, ...]:
        """The present, size>1, not-yet-claimed axes of a role request."""
        keep = []
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            if a is None:
                continue
            if a not in shape:
                dropped.append((role, f"axis {a!r} not in mesh "
                                      f"{tuple(shape)}"))
            elif a in used:
                dropped.append((role, f"axis {a!r} already claimed by "
                                      f"another role"))
            elif shape[a] > 1:
                keep.append(a)
        return tuple(keep)

    # batch ----------------------------------------------------------------
    batch = usable("data", par.batch)
    if batch:
        n = _size(mesh, batch)
        if d.B % n:
            dropped.append(("data", f"batch {d.B} % {n} shards != 0"))
            batch = ()
        else:
            used.update(batch)

    # spatial (regular: the input plane; transposed: the MIRROR input
    # plane, i.e. the transposed layer's output) --------------------------
    (lo_h, hi_h), (lo_w, hi_w) = shard_halo(d)
    h_axis = w_axis = None
    for role, axis, h_i, h_o, s, lo, hi in (
            ("h", par.h, d.H_i, d.H_o, d.s_h, lo_h, hi_h),
            ("w", par.w, d.W_i, d.W_o, d.s_w, lo_w, hi_w)):
        ax = usable(role, axis)
        if not ax:
            continue
        why = _check_spatial(role, shape[ax[0]], h_i, h_o, s, lo, hi)
        if why:
            dropped.append((role, why))
            continue
        used.add(ax[0])
        if role == "h":
            h_axis = ax[0]
        else:
            w_axis = ax[0]

    # channels (x_shape[1] is Cin for both layouts; Cout is w dim 0 for
    # regular OIHW, dim 1 x groups for transposed (C_in, C_out/g, ...)) ----
    cin_n = x_shape[1]
    cout_n = w_shape[1] * spec.groups if transposed else w_shape[0]
    cin_axis = cout_axis = None
    for role, axis, count in (("cin", par.cin, cin_n),
                              ("cout", par.cout, cout_n)):
        ax = usable(role, axis)
        if not ax:
            continue
        if spec.groups > 1:
            dropped.append((role, f"grouped conv (groups={spec.groups}): "
                                  f"channel sharding would split groups"))
            continue
        n = shape[ax[0]]
        if count % n:
            dropped.append((role, f"{role} {count} % {n} shards != 0"))
            continue
        used.add(ax[0])
        if role == "cin":
            cin_axis = ax[0]
        else:
            cout_axis = ax[0]

    return ConvShardPlan(
        mesh=mesh, batch=batch, h=h_axis, w=w_axis,
        cin=cin_axis, cout=cout_axis,
        halo_h=(lo_h, hi_h), halo_w=(lo_w, hi_w),
        transposed=transposed, dropped=tuple(dropped))


# ---------------------------------------------------------------------------
# Halo exchange: gather (fwd/wgrad) and its transpose, scatter-add (dgrad)
# ---------------------------------------------------------------------------

def _record_halo(op: str, axis_name: str, dim: int, send) -> None:
    """One bus event per halo ``ppermute`` send.  Runs at TRACE time, where
    shape/dtype are static, so the per-exchange byte count is exact for
    the lowered collective (per shard) and costs nothing at run time."""
    if obs_events.enabled():
        nbytes = int(math.prod(send.shape)) * send.dtype.itemsize
        obs_events.emit("halo", f"{op}:{axis_name}:dim{dim}",
                        bytes=nbytes, shape=[int(s) for s in send.shape])


def _halo_gather(x, axis_name: str, n: int, lo: int, hi: int, dim: int):
    """Extend a local block with ``lo`` rows from the low neighbor and
    ``hi`` from the high neighbor along ``dim``.  Unnamed ``ppermute``
    destinations receive zeros, so edge shards are extended with exactly
    the zero rows the global padding supplies -- no separate pad path.
    ``hi < 0`` crops instead (adjacent windows do not reach those rows)."""
    with obs_trace.span("halo:gather", axis=axis_name, dim=dim,
                        lo=lo, hi=hi, shards=n):
        parts = []
        if lo > 0:
            send = jax.lax.slice_in_dim(x, x.shape[dim] - lo, x.shape[dim],
                                        axis=dim)
            _record_halo("gather", axis_name, dim, send)
            parts.append(jax.lax.ppermute(
                send, axis_name, [(j, j + 1) for j in range(n - 1)]))
        parts.append(x)
        if hi > 0:
            send = jax.lax.slice_in_dim(x, 0, hi, axis=dim)
            _record_halo("gather", axis_name, dim, send)
            parts.append(jax.lax.ppermute(
                send, axis_name, [(j, j - 1) for j in range(1, n)]))
        out = jnp.concatenate(parts, axis=dim) if len(parts) > 1 else x
        if hi < 0:
            out = jax.lax.slice_in_dim(out, 0, out.shape[dim] + hi, axis=dim)
        return out


def _halo_scatter(x_ext, axis_name: str, n: int, lo: int, hi: int,
                  dim: int, block: int):
    """The exact transpose of :func:`_halo_gather`: fold an extended
    block's overhang rows back onto the neighbors that own them (summing,
    since seam outputs accumulate contributions from both sides).  Edge
    overhang that ``ppermute`` sends to nobody is dropped -- those are
    gradients of padding zeros."""
    with obs_trace.span("halo:scatter", axis=axis_name, dim=dim,
                        lo=lo, hi=hi, shards=n):
        if hi < 0:
            pad = [(0, 0)] * x_ext.ndim
            pad[dim] = (0, -hi)
            x_ext = jnp.pad(x_ext, pad)
            hi = 0
        x = jax.lax.slice_in_dim(x_ext, lo, lo + block, axis=dim)
        if lo > 0:
            send = jax.lax.slice_in_dim(x_ext, 0, lo, axis=dim)
            _record_halo("scatter", axis_name, dim, send)
            recv = jax.lax.ppermute(
                send, axis_name, [(j, j - 1) for j in range(1, n)])
            pad = [(0, 0)] * x.ndim
            pad[dim] = (block - lo, 0)
            x = x + jnp.pad(recv, pad)
        if hi > 0:
            send = jax.lax.slice_in_dim(x_ext, lo + block, lo + block + hi,
                                        axis=dim)
            _record_halo("scatter", axis_name, dim, send)
            recv = jax.lax.ppermute(
                send, axis_name, [(j, j + 1) for j in range(n - 1)])
            pad = [(0, 0)] * x.ndim
            pad[dim] = (0, block - hi)
            x = x + jnp.pad(recv, pad)
        return x


def _gather_spatial(x, plan: ConvShardPlan):
    if plan.h:
        x = _halo_gather(x, plan.h, plan.size(plan.h), *plan.halo_h, dim=2)
    if plan.w:
        x = _halo_gather(x, plan.w, plan.size(plan.w), *plan.halo_w, dim=3)
    return x


def _scatter_spatial(x_ext, plan: ConvShardPlan, blk_h: int, blk_w: int):
    # reverse order of _gather_spatial: scatter is its exact transpose,
    # corner halos retrace their two hops.
    if plan.w:
        x_ext = _halo_scatter(x_ext, plan.w, plan.size(plan.w),
                              *plan.halo_w, dim=3, block=blk_w)
    if plan.h:
        x_ext = _halo_scatter(x_ext, plan.h, plan.size(plan.h),
                              *plan.halo_h, dim=2, block=blk_h)
    return x_ext


def _ext(extent: int, n_shards: int, halo: tuple[int, int],
         sharded: bool) -> int:
    """Local gathered extent of one spatial axis."""
    if not sharded:
        return extent
    return extent // n_shards + halo[0] + halo[1]


def _local_spec(spec: ConvSpec, plan: ConvShardPlan) -> ConvSpec:
    """The per-shard geometry: padding zeroed on sharded axes (the halo
    exchange delivers the edge zeros), untouched elsewhere."""
    ph, pw = spec.padding
    if plan.h:
        ph = (0, 0)
    if plan.w:
        pw = (0, 0)
    return dataclasses.replace(spec, padding=(ph, pw))


def _local_tspec(spec: ConvTransposeSpec,
                 plan: ConvShardPlan) -> ConvTransposeSpec:
    """Transposed mirror of :func:`_local_spec`: padding AND
    output_padding zeroed on sharded axes, so each shard produces the full
    extended mirror plane and the scatter crops/folds the seams."""
    ph, pw = spec.padding
    oh, ow = spec.output_padding
    if plan.h:
        ph, oh = (0, 0), 0
    if plan.w:
        pw, ow = (0, 0), 0
    return dataclasses.replace(spec, padding=(ph, pw),
                               output_padding=(oh, ow))


def _wgrad_axes(plan: ConvShardPlan) -> tuple[str, ...]:
    """weight grad contracts batch x spatial: psum over all three."""
    return plan.batch + tuple(a for a in (plan.h, plan.w) if a)


# ---------------------------------------------------------------------------
# Regular conv: three shard_map lowerings
# ---------------------------------------------------------------------------

def _fwd_regular(x, w, spec: ConvSpec, policy, plan: ConvShardPlan):
    ls = _local_spec(spec, plan)

    def body(xb, wb):
        x_ext = _gather_spatial(xb, plan)
        d = C.spec_dims(x_ext.shape, wb.shape, ls)
        y = C._execute(
            "forward", policy.forward, d, False,
            lambda eng: C._forward(x_ext, C._weight_for(eng, wb, ls),
                                   d, eng, ls.groups))
        if plan.cin:
            y = jax.lax.psum(y, plan.cin)
        return y

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cin, plan.h, plan.w),
                  P(plan.cout, plan.cin, None, None)),
        out_specs=P(plan.batch_spec, plan.cout, plan.h, plan.w),
        check_rep=False)(x, w)


def _dgrad_regular(dy, w, x_shape, spec: ConvSpec, policy,
                   plan: ConvShardPlan):
    ls = _local_spec(spec, plan)
    b_loc = x_shape[0] // plan.size(plan.batch)
    c_loc = x_shape[1] // plan.size(plan.cin)
    blk_h, blk_w = (x_shape[2] // plan.size(plan.h),
                    x_shape[3] // plan.size(plan.w))
    h_ext = _ext(x_shape[2], plan.size(plan.h), plan.halo_h, bool(plan.h))
    w_ext = _ext(x_shape[3], plan.size(plan.w), plan.halo_w, bool(plan.w))

    def body(dyb, wb):
        d = C.spec_dims((b_loc, c_loc, h_ext, w_ext), wb.shape, ls)
        dx_ext = C._execute(
            "input_grad", policy.input_grad, d, False,
            lambda eng: C._input_grad(dyb, C._weight_for(eng, wb, ls),
                                      d, eng, ls.groups))
        if plan.cout:
            dx_ext = jax.lax.psum(dx_ext, plan.cout)
        return _scatter_spatial(dx_ext, plan, blk_h, blk_w)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cout, plan.h, plan.w),
                  P(plan.cout, plan.cin, None, None)),
        out_specs=P(plan.batch_spec, plan.cin, plan.h, plan.w),
        check_rep=False)(dy, w)


def _wgrad_regular(x, dy, w_shape, spec: ConvSpec, policy,
                   plan: ConvShardPlan):
    ls = _local_spec(spec, plan)
    w_loc = (w_shape[0] // plan.size(plan.cout),
             w_shape[1] // plan.size(plan.cin), w_shape[2], w_shape[3])
    reduce_axes = _wgrad_axes(plan)

    def body(xb, dyb):
        x_ext = _gather_spatial(xb, plan)
        d = C.spec_dims(x_ext.shape, w_loc, ls)
        dw = C._execute(
            "weight_grad", policy.weight_grad, d, False,
            lambda eng: C._run_wgrad(x_ext, dyb, d, eng, ls))
        if reduce_axes:
            dw = jax.lax.psum(dw, reduce_axes)
        return dw

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cin, plan.h, plan.w),
                  P(plan.batch_spec, plan.cout, plan.h, plan.w)),
        out_specs=P(plan.cout, plan.cin, None, None),
        check_rep=False)(x, dy)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _sharded_conv2d(x, w, spec, policy, plan):
    return _fwd_regular(x, w, spec, policy, plan)


def _sharded_conv2d_fwd(x, w, spec, policy, plan):
    return _fwd_regular(x, w, spec, policy, plan), (x, w)


def _sharded_conv2d_bwd(spec, policy, plan, res, dy):
    x, w = res
    dx = _dgrad_regular(dy, w, x.shape, spec, policy, plan)
    dw = _wgrad_regular(x, dy, w.shape, spec, policy, plan)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_sharded_conv2d.defvjp(_sharded_conv2d_fwd, _sharded_conv2d_bwd)


# ---------------------------------------------------------------------------
# Transposed conv: every pass is a role-swap over the mirror dims; the
# mirror INPUT plane (= the transposed output) is the halo-exchanged one.
# ---------------------------------------------------------------------------

def _t_fwd(x, w, spec: ConvTransposeSpec, policy, plan: ConvShardPlan,
           y_hw: tuple[int, int]):
    tl = _local_tspec(spec, plan)
    blk_h, blk_w = (y_hw[0] // plan.size(plan.h),
                    y_hw[1] // plan.size(plan.w))

    def body(xb, wb):
        # Local zero-pad/zero-op geometry: the mirror input plane IS the
        # extended block (blk + lo + hi rows); scatter folds the seams.
        d = C.transpose_dims(xb.shape, wb.shape, tl)
        y_ext = C._execute(
            "forward", policy.forward, d, True,
            lambda eng: C._t_forward(xb, wb, d, eng, tl))
        if plan.cin:
            y_ext = jax.lax.psum(y_ext, plan.cin)
        return _scatter_spatial(y_ext, plan, blk_h, blk_w)

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cin, plan.h, plan.w),
                  P(plan.cin, plan.cout, None, None)),
        out_specs=P(plan.batch_spec, plan.cout, plan.h, plan.w),
        check_rep=False)(x, w)


def _t_dgrad(dy, w, x_shape, spec: ConvTransposeSpec, policy,
             plan: ConvShardPlan):
    tl = _local_tspec(spec, plan)
    x_loc = (x_shape[0] // plan.size(plan.batch),
             x_shape[1] // plan.size(plan.cin),
             x_shape[2] // plan.size(plan.h),
             x_shape[3] // plan.size(plan.w))

    def body(dyb, wb):
        dy_ext = _gather_spatial(dyb, plan)
        d = C.transpose_dims(x_loc, wb.shape, tl)
        dx = C._execute(
            "input_grad", policy.input_grad, d, True,
            lambda eng: C._forward(dy_ext, C._weight_for(eng, wb, tl),
                                   d, eng, tl.groups))
        if plan.cout:
            dx = jax.lax.psum(dx, plan.cout)
        return dx

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cout, plan.h, plan.w),
                  P(plan.cin, plan.cout, None, None)),
        out_specs=P(plan.batch_spec, plan.cin, plan.h, plan.w),
        check_rep=False)(dy, w)


def _t_wgrad(dy, x, x_shape, w_shape, spec: ConvTransposeSpec, policy,
             plan: ConvShardPlan):
    tl = _local_tspec(spec, plan)
    x_loc = (x_shape[0] // plan.size(plan.batch),
             x_shape[1] // plan.size(plan.cin),
             x_shape[2] // plan.size(plan.h),
             x_shape[3] // plan.size(plan.w))
    w_loc = (w_shape[0] // plan.size(plan.cin),
             w_shape[1] // plan.size(plan.cout), w_shape[2], w_shape[3])
    reduce_axes = _wgrad_axes(plan)

    def body(dyb, xb):
        dy_ext = _gather_spatial(dyb, plan)
        d = C.transpose_dims(x_loc, w_loc, tl)
        dw = C._execute(
            "weight_grad", policy.weight_grad, d, True,
            lambda eng: C._run_wgrad(dy_ext, xb, d, eng, tl))
        if reduce_axes:
            dw = jax.lax.psum(dw, reduce_axes)
        return dw

    return shard_map(
        body, mesh=plan.mesh,
        in_specs=(P(plan.batch_spec, plan.cout, plan.h, plan.w),
                  P(plan.batch_spec, plan.cin, plan.h, plan.w)),
        out_specs=P(plan.cin, plan.cout, None, None),
        check_rep=False)(dy, x)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _sharded_conv2d_transpose(x, w, spec, policy, plan):
    y_hw = C.conv_transpose_output_shape(x.shape, w.shape, spec)[2:]
    return _t_fwd(x, w, spec, policy, plan, y_hw)


def _sharded_conv2d_transpose_fwd(x, w, spec, policy, plan):
    y_hw = C.conv_transpose_output_shape(x.shape, w.shape, spec)[2:]
    return _t_fwd(x, w, spec, policy, plan, y_hw), (x, w)


def _sharded_conv2d_transpose_bwd(spec, policy, plan, res, dy):
    x, w = res
    dx = _t_dgrad(dy, w, x.shape, spec, policy, plan)
    dw = _t_wgrad(dy, x, x.shape, w.shape, spec, policy, plan)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_sharded_conv2d_transpose.defvjp(_sharded_conv2d_transpose_fwd,
                                 _sharded_conv2d_transpose_bwd)


# ---------------------------------------------------------------------------
# The lowering hook: policy context + per-call plan + event recording
# ---------------------------------------------------------------------------

_STACK: list[tuple[object, object]] = []


def _record_plan(plan: ConvShardPlan, requested) -> None:
    suffix = "_T" if plan.transposed else ""
    for role, reason in plan.dropped:
        C._record_event(f"mesh:drop:{role}")
        if len(C.POLICY_DECISIONS) < C._MAX_DECISIONS:
            C.POLICY_DECISIONS.append({
                "pass": "mesh", "requested": str(requested),
                "engine": f"replicated:{role}", "reason": reason,
                "transpose": plan.transposed, "dims": ()})
    if plan.roles:
        C._record_event(f"mesh:conv2d{suffix}:{plan.tag}")
    else:
        C._record_event(f"mesh:fallback{suffix}")
        if len(C.POLICY_DECISIONS) < C._MAX_DECISIONS:
            C.POLICY_DECISIONS.append({
                "pass": "mesh", "requested": str(requested),
                "engine": "replicated",
                "reason": ("; ".join(r for _, r in plan.dropped)
                           or "no shardable role for this mesh"),
                "transpose": plan.transposed, "dims": ()})


def _maybe_lower(x, w, spec, policy):
    """``repro.core.conv.MESH_LOWERING`` hook: return a sharded lowering
    or ``NotImplemented`` (single-device custom_vjp proceeds)."""
    requested, mesh = _STACK[-1]
    if mesh is None:
        mesh = _active_mesh()
    if mesh is None:
        C._record_event("mesh:no_mesh")
        return NotImplemented
    par = ConvParallel.coerce(requested, mesh)
    plan = plan_conv_sharding(x.shape, w.shape, spec, par, mesh)
    _record_plan(plan, requested)
    if not plan.roles:
        return NotImplemented
    if plan.transposed:
        return _sharded_conv2d_transpose(x, w, spec, policy, plan)
    return _sharded_conv2d(x, w, spec, policy, plan)


@contextlib.contextmanager
def conv_mesh(policy, mesh=None):
    """Scoped mesh-parallel conv lowering for every conv2d /
    conv2d_transpose traced in the dynamic extent::

        with conv_parallel.conv_mesh("tp"):        # or a ConvParallel
            grads = jax.grad(loss)(params, batch)  # convs lower sharded

    ``policy`` is a :class:`ConvParallel`, a ``dist.sharding`` policy name
    (``"tp"`` / ``"dp_only"`` / ``"tp_rep"`` / ``"spatial"``), or None (a
    no-op, so call sites can thread an optional config through).  ``mesh``
    defaults to the enclosing ``with mesh:`` context at trace time.
    Applies at TRACE time, like :func:`repro.core.conv.conv_policy`.
    """
    if policy is None:
        yield None
        return
    if isinstance(policy, str) and policy not in (
            "tp", "tensor_parallel", "dp_only", "tp_rep", "spatial"):
        raise ValueError(f"unknown conv mesh policy {policy!r}")
    _STACK.append((policy, mesh))
    C.MESH_LOWERING = _maybe_lower
    try:
        yield policy
    finally:
        _STACK.pop()
        if not _STACK:
            C.MESH_LOWERING = None
