"""Distributed substrate: sharding rules and activation constraints."""

from repro.dist import sharding
from repro.dist.constraints import constrain_batch, set_activation_policy

__all__ = ["sharding", "constrain_batch", "set_activation_policy"]
