"""Distributed substrate: sharding rules, activation constraints, and
mesh-parallel conv lowerings."""

from repro.dist import sharding
from repro.dist import conv_parallel
from repro.dist.constraints import constrain_batch, set_activation_policy
from repro.dist.conv_parallel import ConvParallel, conv_mesh

__all__ = ["sharding", "conv_parallel", "constrain_batch",
           "set_activation_policy", "ConvParallel", "conv_mesh"]
