"""Activation sharding constraints.

``constrain_batch`` pins the leading (batch) dim of an activation to the mesh
axes chosen by ``set_activation_policy``.  It is a no-op outside a mesh
context, so the same model code runs unsharded on one device and sharded
under ``with mesh:`` without branching at the call sites.
"""

from __future__ import annotations

import jax

_ACT_AXES: tuple[str, ...] | None = None


def set_activation_policy(axes) -> None:
    """axes: mesh axis names the batch dim is sharded over (or None/())."""
    global _ACT_AXES
    _ACT_AXES = tuple(axes) if axes else None


def _active_mesh():
    """The mesh from an enclosing ``with mesh:`` block, if any."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:  # pragma: no cover - private-API drift safety net
        return None


def constrain_batch(x: jax.Array) -> jax.Array:
    axes = _ACT_AXES
    mesh = _active_mesh()
    if not axes or mesh is None or x.ndim == 0:
        return x
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return x
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if x.shape[0] % total != 0:
        return x
    spec = jax.sharding.PartitionSpec(
        axes if len(axes) > 1 else axes[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
