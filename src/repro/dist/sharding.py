"""Path-based sharding rules for params, optimizer state, batches and caches.

All rules operate on pytrees of arrays or ``ShapeDtypeStruct``s and return
trees of ``PartitionSpec`` with the same structure; ``to_shardings`` converts
a spec tree to ``NamedSharding``s for a concrete mesh.  Rules only need axis
*sizes*, so the ``mesh`` argument may be any object with a ``.shape`` mapping
(tests use a stub).

Policies:
  * ``tp``      -- 2-D data x tensor parallelism (default): linear weights
                   shard (d_in="data", d_out="model"); ``wo`` swaps the axes
                   so the attention output projection all-reduces once; the
                   embedding shards vocab over "model"; MoE expert tensors
                   shard experts over "model" (expert parallelism) and d_in
                   over "data".  Batch shards over ("data",).
  * ``dp_only`` -- pure (Zero-style) data parallelism: the "model" axis is
                   dropped from param specs and joins the batch axes instead.
  * ``tp_rep``  -- tensor-parallel activations with fully replicated params
                   (perf-experiment baseline).

Every assignment is divisibility-checked against the mesh axis size; an
indivisible dim falls back to replication for that dim only.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis(mesh, name: str) -> int:
    return dict(mesh.shape).get(name, 1)


def _fit(dim: int, mesh, axis) -> object:
    """axis if dim divides the mesh axis size, else None (replicate)."""
    if axis is None:
        return None
    if isinstance(axis, tuple):
        total = 1
        for a in axis:
            total *= _axis(mesh, a)
    else:
        total = _axis(mesh, axis)
    return axis if total > 0 and dim % total == 0 else None


def batch_axes(mesh, policy: str = "tp") -> tuple[str, ...]:
    """Mesh axes carrying the batch dim under a policy."""
    names = tuple(dict(mesh.shape))
    if policy == "dp_only":
        cand = ("pod", "data", "model")
    else:  # tp / tp_rep: model axis is reserved for tensor parallelism
        cand = ("pod", "data")
    return tuple(a for a in cand if a in names)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

def _leaf_spec(path: tuple[str, ...], leaf, mesh, policy: str) -> P:
    ndim = len(leaf.shape)
    if policy == "tp_rep" or ndim < 2:
        return P()
    lead = [None] * (ndim - 2)
    if "embed" in path:
        d_in, d_out = "model", "data"        # vocab over model, d over data
    elif "moe" in path and "shared" not in path and "router" not in path \
            and ndim >= 3 and path[-1] == "w":
        # Expert tensor (..., E, d_in, d_out): expert parallelism over
        # "model", d_in over "data".
        lead = [None] * (ndim - 3)
        spec = [_fit(leaf.shape[-3], mesh, "model"),
                _fit(leaf.shape[-2], mesh, "data"), None]
        if policy == "dp_only":
            spec = [s if s != "model" else None for s in spec]
        return P(*lead, *spec)
    elif ndim >= 4:
        # Conv kernel ``(..., O, I, kh, kw)`` -- or its transposed twin
        # ``(..., I, O/g, kh, kw)`` under a decoder ("dec") path.  The
        # trailing dims are SPATIAL: a kh x kw kernel is never a matmul,
        # even when kh/kw happen to divide the mesh, so the linear-weight
        # rule must not see it.  Shard Cout over "model" (the conv
        # analogue of d_out="model"; matches conv_parallel's "tp" psum
        # placement) and replicate Cin -- "data" is taken by the batch.
        out_dim = ndim - 3 if "dec" in path else ndim - 4
        spec = [None] * ndim
        if policy != "dp_only":
            spec[out_dim] = _fit(leaf.shape[out_dim], mesh, "model")
        return P(*spec)
    elif "wo" in path:
        d_in, d_out = "model", "data"        # output proj: swapped axes
    else:
        d_in, d_out = "data", "model"
    spec = [_fit(leaf.shape[-2], mesh, d_in),
            _fit(leaf.shape[-1], mesh, d_out)]
    if policy == "dp_only":
        spec = [s if s != "model" else None for s in spec]
    return P(*lead, *spec)


def param_specs(params, mesh, policy: str = "tp"):
    """PartitionSpec tree mirroring a parameter tree."""
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            # Per-stage conv stacks ({"enc": [layer, ...]}) keep their
            # container type so the spec tree mirrors the param tree.
            out = [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
            return tuple(out) if isinstance(tree, tuple) else out
        return _leaf_spec(path, tree, mesh, policy)
    return walk(params, ())


def opt_state_specs(params, mesh, policy: str = "tp"):
    """Specs for ``adamw.init_state(params)``: m/v inherit the param specs."""
    ps = param_specs(params, mesh, policy)
    return {"m": ps, "v": ps, "step": P()}


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------

def _dim_spec(axes: tuple[str, ...], dim: int, mesh):
    axis = axes if len(axes) > 1 else (axes[0] if axes else None)
    return _fit(dim, mesh, axis)


def batch_specs(batch, mesh, policy: str = "tp"):
    """Shard the leading dim of every batch leaf over the batch axes."""
    axes = batch_axes(mesh, policy)

    def leaf(x):
        ndim = len(x.shape)
        if ndim == 0 or not axes:
            return P()
        return P(_dim_spec(axes, x.shape[0], mesh), *([None] * (ndim - 1)))
    return jax.tree.map(leaf, batch)


def cache_specs(cache, mesh, policy: str = "tp"):
    """Decode caches are stacked (L, B, ...): shard the batch dim (dim 1)."""
    axes = batch_axes(mesh, policy)

    def leaf(x):
        ndim = len(x.shape)
        if ndim < 2 or not axes:
            return P()
        return P(None, _dim_spec(axes, x.shape[1], mesh),
                 *([None] * (ndim - 2)))
    return jax.tree.map(leaf, cache)


# ---------------------------------------------------------------------------
# Spec tree -> shardings
# ---------------------------------------------------------------------------

def to_shardings(specs, mesh):
    """PartitionSpec tree (or a single spec) -> NamedSharding tree."""
    if isinstance(specs, P):
        return NamedSharding(mesh, specs)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
