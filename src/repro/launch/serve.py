"""Serving launcher: batched request serving on a smoke-scale model.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 8 --max-new 16 --engine continuous

``--engine static`` runs the wave-batched baseline
(``repro.serve.engine``); ``--engine continuous`` (default) runs the
slotted-cache continuous-batching engine (``repro.serve.continuous``).
``--deadline-s`` gives every request a wall-clock budget: overdue
requests finalize with partial output and ``status="timed_out"`` instead
of stalling the batch.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serve.continuous import ContinuousEngine
from repro.serve.engine import Engine
from repro.serve.request import Request

ENGINES = {"static": Engine, "continuous": ContinuousEngine}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--engine", choices=sorted(ENGINES), default="continuous",
                    help="wave-batched baseline or slotted continuous "
                         "batching (default)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget in seconds; "
                         "overdue requests finalize with partial output "
                         "and status='timed_out'")
    ap.add_argument("--conv-policy", default=None,
                    help="per-pass conv engine policy for the decode path "
                         "(e.g. 'auto', 'bp_phase', or "
                         "'fwd=...,dgrad=...,wgrad=...')")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    model = M.build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    eng = ENGINES[args.engine](
        cfg, params, max_batch=args.max_batch,
        max_len=args.prompt_len + args.max_new + 2,
        temperature=args.temperature, seed=args.seed,
        conv_policy=args.conv_policy)
    rng = np.random.RandomState(args.seed)
    for rid in range(args.requests):
        eng.submit(Request(
            rid=rid,
            prompt=rng.randint(0, cfg.vocab, args.prompt_len).tolist(),
            max_new=args.max_new,
            deadline_s=args.deadline_s))
    t0 = time.perf_counter()
    done = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.out) for r in done)
    by_status = {}
    for r in done:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    lat = sorted(r.t_done - r.t_submit for r in done
                 if r.t_done is not None)
    p50 = lat[len(lat) // 2] if lat else float("nan")
    print(f"[serve] arch={cfg.name} engine={args.engine} "
          f"requests={len(done)} tokens={n_tok} "
          f"wall={dt:.2f}s ({n_tok/dt:.1f} tok/s) "
          f"p50_latency={p50:.2f}s status={by_status}")
    for r in done[:3]:
        print(f"  req{r.rid}: {r.out[:10]}... [{r.status}]")
    return done


if __name__ == "__main__":
    main()
