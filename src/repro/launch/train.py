"""Training launcher: end-to-end driver wiring every substrate together.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised here (and by examples/ + tests):
  * deterministic restartable data pipeline (resume replays nothing);
  * jitted train step (loss + AdamW + schedule) with optional microbatch
    accumulation;
  * step-atomic checkpoints with rotation + async write;
  * straggler/heartbeat bookkeeping hooks (single-process here; the same
    objects drive the restart plan in the multi-worker deployment);
  * mesh-aware sharding when >1 device is visible (CPU: 1 device).
"""

from __future__ import annotations

import argparse
import contextlib
import time
import warnings

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, make_batch
from repro.ckpt import checkpoint as CKPT
from repro.ft import inject
from repro.ft.failures import (GuardState, HeartbeatTable, StragglerDetector,
                               make_guard_restart_plan)
from repro.models import model as M
from repro.optim import adamw
from repro.train import train_step as TS
from repro import obs


def resolve_conv_policy_args(conv_policy: str | None,
                             conv_mode: str | None) -> str | None:
    """Map the CLI pair onto one policy string; --conv-mode is the
    deprecated uniform spelling and may not be combined with
    --conv-policy."""
    if conv_mode is not None:
        warnings.warn("--conv-mode is deprecated; use --conv-policy "
                      "(same engine names; per-pass via "
                      "fwd=...,dgrad=...,wgrad=...)", DeprecationWarning,
                      stacklevel=2)
        if conv_policy is not None:
            raise SystemExit(
                "pass either --conv-policy or the deprecated --conv-mode, "
                "not both")
        return conv_mode
    return conv_policy


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--stop-after", type=int, default=None,
                    help="simulate preemption: stop at this step while the "
                         "schedule still targets --steps")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--conv-policy", default=None,
                    help="per-pass conv engine policy, e.g. 'auto', "
                         "'pallas' (uniform) or "
                         "'fwd=pallas,dgrad=auto,wgrad=bp_phase' "
                         "(default: cfg.conv_policy)")
    ap.add_argument("--conv-mode", default=None,
                    choices=["lax", "traditional", "bp_im2col", "bp_phase",
                             "pallas"],
                    help="DEPRECATED: uniform spelling of --conv-policy")
    ap.add_argument("--conv-mesh", default=None,
                    choices=["tp", "dp_only", "spatial"],
                    help="mesh-parallel conv lowering over this host's "
                         "devices (repro.dist.conv_parallel): batch/"
                         "channel/spatial sharding with halo exchange; "
                         "layers the mesh cannot shard fall back with a "
                         "recorded reason")
    ap.add_argument("--autotune", default=None,
                    choices=["off", "measure", "cached"],
                    help="measured autotuning of the Pallas tile plans "
                         "(repro.config.autotune): 'measure' times the "
                         "top-k candidates and persists the winners, "
                         "'cached' reuses persisted winners without timing")
    ap.add_argument("--plan-cache-dir", default=None,
                    help="persistent plan-cache directory "
                         "(repro.config.plan_cache_dir; default: next to "
                         "jax's compilation cache)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-spec", default=None,
                    help="arm the fault injector (repro.config.fault_spec), "
                         "e.g. 'pallas.*:raise@step3;grad.values:nan@step5'")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable telemetry and write a Chrome/Perfetto "
                         "trace_event JSON of the run (repro.obs.trace) "
                         "to PATH")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable telemetry and stream per-step metrics "
                         "JSONL (loss/grad_norm/guard/dispatch mix) to PATH")
    guard_group = ap.add_mutually_exclusive_group()
    guard_group.add_argument("--guard", dest="guard", action="store_true",
                             default=True,
                             help="in-graph numerical guard: skip non-finite "
                                  "steps, escalate to clip then rollback "
                                  "(default: on)")
    guard_group.add_argument("--no-guard", dest="guard",
                             action="store_false")
    ap.add_argument("--guard-clip-after", type=int, default=2,
                    help="consecutive bad steps before the tighter grad "
                         "clip engages")
    ap.add_argument("--guard-rollback-after", type=int, default=4,
                    help="consecutive bad steps before restoring the last "
                         "committed checkpoint")
    args = ap.parse_args(argv)

    if args.autotune is not None or args.plan_cache_dir is not None \
            or args.fault_spec is not None or args.trace is not None \
            or args.metrics is not None:
        from repro.core.config import config
        updates = {}
        if args.autotune is not None:
            updates["autotune"] = args.autotune
        if args.plan_cache_dir is not None:
            updates["plan_cache_dir"] = args.plan_cache_dir
        if args.fault_spec is not None:
            updates["fault_spec"] = args.fault_spec
        if args.trace is not None:
            updates.update(telemetry=True, trace_path=args.trace)
        if args.metrics is not None:
            updates.update(telemetry=True, metrics_path=args.metrics)
        config.update(**updates)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "ssm":
        assert args.seq % 128 == 0 or args.seq <= 128, \
            "mamba2 chunking needs seq % 128 == 0 (or <= 128)"
    model = M.build_model(cfg)
    dcfg = DataConfig(seed=args.seed, seq_len=args.seq,
                      global_batch=args.batch, vocab=cfg.vocab)

    opt_cfg = adamw.AdamWConfig(peak_lr=args.lr)
    guard_cfg = TS.GuardConfig(clip_after=args.guard_clip_after) \
        if args.guard else None
    mesh_ctx = contextlib.nullcontext()
    if args.conv_mesh:
        from repro.dist import set_activation_policy, sharding
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        set_activation_policy(sharding.batch_axes(mesh, args.conv_mesh))
        mesh_ctx = mesh                 # with mesh: the step traces sharded
    step_fn = jax.jit(TS.make_train_step(
        cfg, opt_cfg, total_steps=args.steps,
        warmup=max(1, args.steps // 20), accum_steps=args.accum,
        conv_policy=resolve_conv_policy_args(args.conv_policy,
                                             args.conv_mode),
        conv_mesh=args.conv_mesh,
        guard=guard_cfg))

    start_step = 0
    params = opt_state = None
    if args.ckpt_dir:
        start_step_, restored = CKPT.restore(args.ckpt_dir)
        if restored is not None:
            start_step = start_step_ + 1
            params = jax.tree.map(jnp.asarray, restored["params"])
            opt_state = jax.tree.map(jnp.asarray, restored["opt"])
            print(f"[train] resumed from step {start_step_}")
    if params is None:
        params = model.init(jax.random.PRNGKey(args.seed))
        opt_state = adamw.init_state(params)
    n_params = model.param_count(params)
    print(f"[train] arch={cfg.name} params={n_params:,} "
          f"active={model.active_param_count(params):,}")

    hb = HeartbeatTable(n_workers=1)
    straggler = StragglerDetector(n_workers=1)
    gs = GuardState(clip_after=args.guard_clip_after,
                    rollback_after=args.guard_rollback_after) \
        if args.guard else None
    losses = []
    end_step = min(args.steps, args.stop_after) if args.stop_after \
        else args.steps
    for step in range(start_step, end_step):
        t0 = time.perf_counter()
        inject.set_step(step)
        batch = jax.tree.map(jnp.asarray, make_batch(cfg, dcfg, step))
        with obs.trace.span("train:step", step=step):
            with mesh_ctx:              # ambient mesh for the sharded trace
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch, jnp.int32(step))
            loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        obs.metrics.train_step(step, metrics, step_s=dt)
        hb.beat(0)
        straggler.observe([dt])
        if gs is not None and float(metrics.get("guard_bad", 0.0)):
            action = gs.observe(True)
            obs.events.emit("train", f"guard:{action or 'skip'}", step=step,
                            streak=gs.bad_streak)
            print(f"[train] step={step} non-finite step dropped "
                  f"(streak={gs.bad_streak}, action={action})", flush=True)
            if action == "rollback":
                # In-graph skip+clip did not stop the streak: restore the
                # last committed checkpoint (fresh init when none exists).
                CKPT.wait()
                ckpt_steps = CKPT.latest_steps(args.ckpt_dir) \
                    if args.ckpt_dir else []
                plan = make_guard_restart_plan(gs, ckpt_steps)
                print(f"[train] {plan.note}", flush=True)
                if ckpt_steps:
                    _, restored = CKPT.restore(args.ckpt_dir)
                    params = jax.tree.map(jnp.asarray, restored["params"])
                    opt_state = jax.tree.map(jnp.asarray, restored["opt"])
                else:
                    params = model.init(jax.random.PRNGKey(args.seed))
                    opt_state = adamw.init_state(params)
                gs.rolled_back()
        elif gs is not None:
            gs.observe(False)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            CKPT.save(args.ckpt_dir, step,
                      {"params": params, "opt": opt_state}, blocking=True)
    if args.ckpt_dir:
        CKPT.save(args.ckpt_dir, end_step - 1,
                  {"params": params, "opt": opt_state}, blocking=True)
    CKPT.wait()                       # join any async write before exit
    if gs is not None and gs.total_bad:
        print(f"[train] guard: {gs.total_bad} non-finite steps dropped, "
              f"{gs.rollbacks} rollbacks")
    if obs.enabled():
        rep = obs.finalize()
        print(f"[train] obs: {rep['events_total']} events "
              f"{rep['events_by_kind']} trace={rep['trace_file']} "
              f"metrics={rep['metrics']['lines']} lines")
        if not rep["consistent"]:
            raise SystemExit("[train] telemetry divergence: legacy counters "
                             "disagree with the bus-backed views: "
                             + "; ".join(rep["divergences"]))
    print(f"[train] done: first_loss={losses[0]:.4f} "
          f"last_loss={losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
