"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (module import never touches jax
device state).  Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
pure data parallelism so only gradient all-reduces cross the slow inter-pod
links.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
