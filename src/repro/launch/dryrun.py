import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (no mismatched collectives / specs);
  * the step fits (memory_analysis bytes per device);
  * and extracts the roofline inputs: HLO FLOPs, HLO bytes accessed
    (cost_analysis) and collective traffic (parsed from the compiled HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # 40 cells, 1 pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Results land in reports/dryrun/<arch>__<shape>__<mesh>.json for
benchmarks/roofline.py to consume.
"""

import argparse
import json
import re
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import all_arch_ids, get_config
from repro.core.config import config
from repro.configs.base import ArchConfig, SHAPES, ShapeCfg, applicable_shapes
from repro.dist import sharding as SH
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import train_step as TS

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                       r"pred|c64|c128)\[([0-9,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],{}: ]*?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[a-z\-]*\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str, n_devices: int) -> dict[str, float]:
    """Per-device ICI link traffic summed per collective kind.

    Shapes in SPMD-partitioned HLO are PER-PARTITION.  Ring-collective
    accounting per participating device, with p participants and result
    bytes R (per partition):
        all-reduce        2 (p-1)/p * R
        all-gather          (p-1)/p * R      (R = gathered output)
        reduce-scatter      (p-1)   * R      (R = scattered output)
        all-to-all          (p-1)/p * R
        collective-permute            R      (one hop)
    ``total`` is the per-device link-byte sum -- the numerator of the
    collective roofline term (divide by per-chip link bandwidth).
    ``raw_result_bytes`` keeps the unweighted per-partition result sizes.
    """
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    raw = 0.0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ROOT"):
            stripped = stripped[4:].lstrip()
        m = _LINE_RE.search(stripped)
        if m is None:
            continue
        rtype, base = m.group(1), m.group(2)
        rbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(rtype))
        if rbytes == 0:
            continue
        gm = _GROUPS_RE.search(stripped)
        p = int(gm.group(2)) if gm else n_devices
        p = max(p, 2)
        if base == "all-reduce":
            traffic = 2 * (p - 1) / p * rbytes
        elif base == "all-gather":
            traffic = (p - 1) / p * rbytes
        elif base == "reduce-scatter":
            traffic = (p - 1) * rbytes
        elif base == "all-to-all":
            traffic = (p - 1) / p * rbytes
        else:  # collective-permute
            traffic = float(rbytes)
        out[base] += traffic
        raw += rbytes
    out["total"] = sum(out[c] for c in COLLECTIVE_OPS)
    out["raw_result_bytes"] = raw
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict:
    b, l = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            batch = {"frontend": sd((b, l, cfg.d_frontend), f32),
                     "targets": sd((b, l), i32)}
        elif cfg.family == "vlm":
            lt = l - cfg.frontend_tokens
            batch = {"tokens": sd((b, lt), i32),
                     "targets": sd((b, lt), i32),
                     "frontend": sd((b, cfg.frontend_tokens, cfg.d_frontend),
                                    f32)}
        else:
            batch = {"tokens": sd((b, l), i32), "targets": sd((b, l), i32)}
        if shape.kind == "prefill":
            batch.pop("targets", None)
        return batch
    # decode / long_decode: one new token against a seq_len cache
    cache = jax.eval_shape(lambda: T.init_cache(cfg, b, l))
    return {"tokens": sd((b,), i32), "pos": sd((), i32), "cache": cache}


# ---------------------------------------------------------------------------
# Step functions to lower
# ---------------------------------------------------------------------------

def make_cell(cfg: ArchConfig, shape: ShapeCfg, mesh, policy: str = "tp"):
    """Returns (fn, arg_structs, in_shardings, out_shardings)."""
    p_struct = jax.eval_shape(partial(M.init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    p_spec = SH.param_specs(p_struct, mesh, policy)
    p_shard = SH.to_shardings(p_spec, mesh)

    if shape.kind == "train":
        o_struct = jax.eval_shape(adamw.init_state, p_struct)
        o_spec = SH.opt_state_specs(p_struct, mesh, policy)
        o_shard = SH.to_shardings(o_spec, mesh)
        batch = input_specs(cfg, shape)
        b_shard = SH.to_shardings(SH.batch_specs(batch, mesh, policy), mesh)
        step_fn = TS.make_train_step(cfg, adamw.AdamWConfig())

        def fn(params, opt_state, batch_, step):
            return step_fn(params, opt_state, batch_, step)

        args = (p_struct, o_struct, batch,
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_shard, o_shard, b_shard,
                 SH.to_shardings(jax.sharding.PartitionSpec(), mesh))
        out_sh = (p_shard, o_shard, None)
        return fn, args, in_sh, out_sh

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        b_shard = SH.to_shardings(SH.batch_specs(batch, mesh, policy), mesh)

        def fn(params, batch_):
            logits, _ = M.forward(params, batch_, cfg)
            return logits

        return fn, (p_struct, batch), (p_shard, b_shard), None

    # decode / long_decode: serve_step
    specs = input_specs(cfg, shape)
    cache_struct = specs["cache"]
    c_shard = SH.to_shardings(SH.cache_specs(cache_struct, mesh, policy), mesh)
    t_shard = SH.to_shardings(SH.batch_specs(
        {"t": specs["tokens"]}, mesh, policy), mesh)["t"]
    s_shard = SH.to_shardings(jax.sharding.PartitionSpec(), mesh)

    def fn(params, cache, tokens, pos):
        return M.decode_step(params, cache, tokens, pos, cfg)

    args = (p_struct, cache_struct, specs["tokens"], specs["pos"])
    in_sh = (p_shard, c_shard, t_shard, s_shard)
    out_sh = (None, c_shard)
    return fn, args, in_sh, out_sh


def model_flops(cfg: ArchConfig, shape: ShapeCfg, p_struct) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D; decode: D = new tokens."""
    n_params = sum(x.size for x in jax.tree.leaves(p_struct))
    if cfg.n_experts:
        # count expert weights at top_k/E of size
        def active(path_leaf):
            return path_leaf
        total = 0
        def walk(tree, path):
            nonlocal total
            if isinstance(tree, dict):
                for k, v in tree.items():
                    walk(v, path + (k,))
                return
            if "moe" in "/".join(path) and tree.ndim >= 3 \
                    and tree.shape[-3] == cfg.n_experts:
                total += tree.size * cfg.moe_top_k // cfg.n_experts
            else:
                total += tree.size
        walk(p_struct, ())
        n_params = total
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
        mult = 6 if shape.kind == "train" else 2
    else:
        tokens = shape.global_batch          # one token per sequence
        mult = 2
    return float(mult) * n_params * tokens


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool,
             report_dir: str = REPORT_DIR, policy: str = "tp",
             window_skip: bool = False, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    from repro.dist.constraints import set_activation_policy
    from repro.models import attention as ATT
    ATT.WINDOW_SKIP = window_skip
    set_activation_policy(SH.batch_axes(mesh, policy))
    t0 = time.time()
    with mesh:
        fn, args, in_sh, out_sh = make_cell(cfg, shape, mesh, policy)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    t1 = time.time()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    n_dev = mesh.devices.size
    cbytes = collective_bytes(compiled.as_text(), n_dev)
    p_struct = jax.eval_shape(partial(M.init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    cache_bytes = 0
    if shape.kind in ("decode", "long_decode"):
        cache_struct = input_specs(cfg, shape)["cache"]
        cache_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(cache_struct))
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "policy": policy,
        "window_skip": window_skip,
        "remat": cfg.remat if config.remat is None else config.remat,
        "ssd_chunk": str(config.ssd_chunk),
        "n_devices": n_dev,
        "kind": shape.kind,
        "cache_bytes": cache_bytes,
        "compile_s": round(t1 - t0, 2),
        # cost_analysis shapes are per-partition: scale to global.
        "flops_per_device": float(cost.get("flops", -1.0)),
        "flops": float(cost.get("flops", -1.0)) * n_dev,
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)) * n_dev,
        "collective_bytes": cbytes,
        "model_flops": model_flops(cfg, shape, p_struct),
        "memory": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_in_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    }
    os.makedirs(report_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(report_dir,
                            f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] {arch} {shape_name} mesh={mesh_name} "
          f"compile={result['compile_s']}s flops={result['flops']:.3e} "
          f"coll={cbytes['total']:.3e}B")
    print(f"  memory_analysis: {result['memory']}")
    return result


def run_conv_cell(policy: str = "tp", multi_pod: bool = False,
                  report_dir: str = REPORT_DIR, tag: str = "") -> dict:
    """Compile the mesh-parallel conv autoencoder train step on the
    production mesh and GATE that the sharded lowering was actually taken.

    ``policy`` is a ``repro.dist.conv_parallel`` policy name: ``tp``
    (batch over "data", Cout over "model"), ``dp_only`` (pure data
    parallelism) or ``spatial`` (batch over "data", H over "model" with
    halo exchange -- the cell then must emit collective-permutes).  Convs
    the mesh cannot shard (e.g. the final decoder's Cout=3 under tp) fall
    back per-role; the recorded reasons land in the report.
    """
    from repro.core import conv as CONV
    from repro.dist.constraints import set_activation_policy
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    # Spatial policy replicates params (activation sharding is the point);
    # tp/dp_only reuse the matching param rules.
    param_policy = "tp_rep" if policy == "spatial" else policy
    set_activation_policy(SH.batch_axes(mesh, param_policy))
    acfg = M.AutoencoderConfig(c_in=3, widths=(16, 32), k=3,
                               conv_policy="lax")
    # Batch must divide the full batch-axis extent (dp_only: every axis).
    n_batch = 1
    for a in SH.batch_axes(mesh, param_policy):
        n_batch *= dict(mesh.shape)[a]
    b, size = 2 * n_batch, 64
    p_struct = jax.eval_shape(partial(M.init_autoencoder, cfg=acfg),
                              jax.random.PRNGKey(0))
    p_shard = SH.to_shardings(SH.param_specs(p_struct, mesh, param_policy),
                              mesh)
    o_struct = jax.eval_shape(adamw.init_state, p_struct)
    o_shard = SH.to_shardings(SH.opt_state_specs(p_struct, mesh,
                                                 param_policy), mesh)
    batch = {"image": jax.ShapeDtypeStruct((b, acfg.c_in, size, size),
                                           jnp.float32)}
    b_shard = SH.to_shardings(SH.batch_specs(batch, mesh, param_policy),
                              mesh)
    step_fn = TS.make_train_step(acfg, adamw.AdamWConfig(),
                                 loss=M.autoencoder_loss, conv_mesh=policy)
    CONV.reset_dispatch_events()
    t0 = time.time()
    with mesh:
        jitted = jax.jit(step_fn,
                         in_shardings=(p_shard, o_shard, b_shard,
                                       SH.to_shardings(
                                           jax.sharding.PartitionSpec(),
                                           mesh)),
                         out_shardings=(p_shard, o_shard, None))
        compiled = jitted.lower(
            p_struct, o_struct, batch,
            jax.ShapeDtypeStruct((), jnp.int32)).compile()
    t1 = time.time()
    events = {k: v for k, v in CONV.dispatch_events().items()
              if k.startswith("mesh:")}
    sharded = sum(v for k, v in events.items()
                  if k.startswith("mesh:conv2d"))
    fallbacks = [p["reason"] for p in CONV.policy_decisions()
                 if p["pass"] == "mesh"]
    n_dev = mesh.devices.size
    cbytes = collective_bytes(compiled.as_text(), n_dev)
    mem = compiled.memory_analysis()
    if sharded == 0:
        raise SystemExit(
            f"[dryrun] conv cell policy={policy}: NO conv took the sharded "
            f"path (silent replication); events={events} "
            f"reasons={fallbacks}")
    if policy == "spatial" and cbytes["collective-permute"] == 0:
        raise SystemExit(
            f"[dryrun] conv cell policy=spatial compiled without any "
            f"collective-permute: halo exchange was optimized away or "
            f"never emitted; events={events}")
    result = {
        "arch": acfg.name,
        "shape": f"ae_train_{size}",
        "mesh": mesh_name,
        "policy": policy,
        "n_devices": n_dev,
        "kind": "train",
        "compile_s": round(t1 - t0, 2),
        "mesh_events": events,
        "sharded_convs": sharded,
        "fallback_reasons": fallbacks,
        "collective_bytes": cbytes,
        "memory": {
            "argument_size_in_bytes": getattr(mem, "argument_size_in_bytes",
                                              None),
            "output_size_in_bytes": getattr(mem, "output_size_in_bytes",
                                            None),
            "temp_size_in_bytes": getattr(mem, "temp_size_in_bytes", None),
        },
    }
    os.makedirs(report_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    out_path = os.path.join(
        report_dir, f"{acfg.name}__conv_{policy}__{mesh_name}{suffix}.json")
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    print(f"[dryrun] conv cell policy={policy} mesh={mesh_name} "
          f"compile={result['compile_s']}s sharded_convs={sharded} "
          f"permute={cbytes['collective-permute']:.3e}B "
          f"events={events}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report-dir", default=REPORT_DIR)
    ap.add_argument("--policy", default="tp",
                choices=["tp", "dp_only", "tp_rep"])
    ap.add_argument("--conv", default=None,
                    choices=["tp", "dp_only", "spatial"],
                    help="compile the mesh-parallel conv autoencoder cell "
                         "under this conv_parallel policy instead of the "
                         "LM cells")
    ap.add_argument("--window-skip", action="store_true")
    ap.add_argument("--tag", default="",
                    help="suffix for the report file (perf iterations)")
    args = ap.parse_args()

    if args.conv:
        run_conv_cell(args.conv, multi_pod=args.multi_pod,
                      report_dir=args.report_dir, tag=args.tag)
        return

    cells = []
    if args.all:
        for a in all_arch_ids():
            for s in applicable_shapes(get_config(a)):
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, args.multi_pod, args.report_dir,
                     policy=args.policy, window_skip=args.window_skip,
                     tag=args.tag)
        except Exception as e:  # noqa: BLE001 -- report and continue
            failures.append((a, s, repr(e)[:200]))
            print(f"[dryrun] FAIL {a} {s}: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print(f"[dryrun] all {len(cells)} cells passed")


if __name__ == "__main__":
    main()
