"""Top-level model API used by the trainer, server, dry-run and tests.

    model = build_model(cfg)
    params = model.init(rng)
    logits, aux = model.forward(params, batch)       # training / prefill
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.decode_step(params, cache, tokens, pos)

Batch conventions (produced by repro.data and input_specs in launch):
  LM            : {"tokens": (B, L) i32, "targets": (B, L) i32}
  VLM           : + {"frontend": (B, F, d_frontend)}; tokens cover L - F text
                  positions (image tokens occupy the first F slots)
  audio encoder : {"frontend": (B, L, d_frontend), "targets": (B, L)}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.constraints import constrain_batch
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    forward: Callable[..., Any]
    init_cache: Callable[..., Any]
    decode_step: Callable[..., Any]
    prefill: Callable[..., Any]
    param_count: Callable[[Any], int]
    active_param_count: Callable[[Any], int]


def init_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 6)
    params = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": L.init_rmsnorm(cfg.d_model, cfg.dtype),
    }
    params.update(T.init_stacks(ks[1], cfg))
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(ks[2], cfg.d_model, cfg.vocab,
                                          cfg.dtype)
    if cfg.frontend:
        params["frontend_proj"] = L.init_linear(
            ks[3], cfg.d_frontend, cfg.d_model, cfg.dtype)
    if cfg.mtp_depth:
        k_mtp = jax.random.split(ks[4], cfg.mtp_depth)
        params["mtp"] = {
            "proj": L.init_linear(ks[5], 2 * cfg.d_model, cfg.d_model,
                                  cfg.dtype),
            "block": T.init_attn_block(k_mtp[0], cfg, cfg.mtp_depth, False),
            "norm_h": L.init_rmsnorm(cfg.d_model, cfg.dtype),
            "norm_e": L.init_rmsnorm(cfg.d_model, cfg.dtype),
        }
    return params


def _lm_head(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x)
    return L.linear(params["lm_head"], x)


def _embed_inputs(params, batch, cfg: ArchConfig):
    """Assemble the input sequence (B, L, D) per family."""
    if cfg.family == "audio":
        return L.linear(params["frontend_proj"],
                        batch["frontend"].astype(cfg.adtype))
    x = L.embed(params["embed"], batch["tokens"]).astype(cfg.adtype)
    if cfg.family == "vlm" and "frontend" in batch:
        img = L.linear(params["frontend_proj"],
                       batch["frontend"].astype(cfg.adtype))
        x = jnp.concatenate([img, x], axis=1)
    return x


def forward(params, batch, cfg: ArchConfig):
    """Returns (logits (B, L_pred, V), aux dict).

    For VLM the logits cover only the text positions (image positions are
    dropped before the head, saving a (F x V) matmul slab).
    """
    x = constrain_batch(_embed_inputs(params, batch, cfg))
    x, aux = T.forward_stacks(params, x, cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.family == "vlm" and "frontend" in batch:
        x = x[:, batch["frontend"].shape[1]:, :]
    logits = _lm_head(params, cfg, x)
    if cfg.mtp_depth and "tokens" in batch:
        aux = dict(aux)
        aux["mtp_logits"] = _mtp_forward(params, batch, x, cfg)
    return logits, aux


def _mtp_forward(params, batch, h, cfg: ArchConfig):
    """DeepSeek-V3 multi-token prediction (depth 1, simplified to the
    published structure): h'_t = W[norm(h_t); norm(E(t_{t+1}))] -> block ->
    shared head, predicting token t+2."""
    p = params["mtp"]
    nxt = jnp.roll(batch["tokens"], -1, axis=1)
    e = L.embed(params["embed"], nxt).astype(h.dtype)
    hcat = jnp.concatenate(
        [L.rmsnorm(p["norm_h"], h, cfg.norm_eps),
         L.rmsnorm(p["norm_e"], e, cfg.norm_eps)], axis=-1)
    hm = L.linear(p["proj"], hcat)
    blk = jax.tree.map(lambda a: a[0], p["block"])
    hm, _ = T.attn_block(blk, hm, cfg, use_moe=False)
    return _lm_head(params, cfg, hm)


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """tokens (B,) int32 -> (logits (B, V), cache).

    ``pos`` is a scalar int32 (wave batching: one shared position clock)
    or a per-lane (B,) int32 vector (continuous batching: every cache lane
    sits at its own position; the attention caches scatter per lane)."""
    x = constrain_batch(
        L.embed(params["embed"], tokens[:, None]).astype(cfg.adtype))
    x, cache = T.decode_stacks(params, cache, x, pos, cfg)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _lm_head(params, cfg, x)[:, 0], cache


def prefill(params, tokens, cfg: ArchConfig, max_len: int):
    """Prefill a prompt through the decode path in ONE dispatch.

    tokens (B, P) int32 -> (last-position logits (B, V), cache).  A
    ``lax.scan`` carries the growing cache over the P positions, so the
    whole prompt lowers as one compiled program -- the continuous engine
    jits this per (B, P, max_len) signature, prefilling a fresh batch-1
    cache that :func:`repro.serve.cache.lane_insert` then writes into a
    freed slot of the serving batch."""
    plen = tokens.shape[1]
    cache = T.init_cache(cfg, tokens.shape[0], max_len)

    def step(carry, t):
        logits, carry = decode_step(params, carry, tokens[:, t], t, cfg)
        return carry, logits

    cache, logits = jax.lax.scan(step, cache, jnp.arange(plen))
    return logits[-1], cache


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def count_active_params(params, cfg: ArchConfig) -> int:
    """MoE: experts count at top_k/E of their size (active share)."""
    if not cfg.n_experts:
        return count_params(params)
    total = 0
    def walk(tree, in_expert):
        nonlocal total
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, in_expert or k in ("wi", "wg", "wo"))
            return
        total += tree.size
    # Expert tensors are the (E, d, f) weights inside "blocks_moe"/"moe".
    def walk2(tree, path):
        nonlocal total
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk2(v, path + (k,))
            return
        if "moe" in path and path[-2:] != ("router", "w") and \
                any(p in ("wi", "wg", "wo") for p in path) and \
                "shared" not in path and tree.ndim >= 3 and \
                tree.shape[-3] == cfg.n_experts:
            total += tree.size * cfg.moe_top_k // cfg.n_experts
        else:
            total += tree.size
    walk2(params, ())
    return total


# ---------------------------------------------------------------------------
# Conv autoencoder: strided conv encoder + transposed-conv decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutoencoderConfig:
    """A small conv -> conv_transpose autoencoder (the decoder is the
    transposed-conv workload of ISSUE 5: every upsampling layer goes
    through ``conv2d_transpose``, never a hand-rolled zero-insertion).

    Duck-compatible with the ``ArchConfig`` fields ``make_train_step``
    reads (``name`` / ``conv_policy`` / ``conv_mode``), so the autoencoder
    trains through the exact same jitted step as the LM families."""

    name: str = "conv_autoencoder"
    c_in: int = 3
    widths: tuple[int, ...] = (16, 32)    # encoder channel widths, stride 2
    k: int = 3
    param_dtype: str = "float32"
    conv_policy: str = "auto"
    conv_mode: Optional[str] = None

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def conv_engine_policy(self) -> str:
        if self.conv_mode is not None:
            return self.conv_mode
        return self.conv_policy


def init_autoencoder(key, cfg: AutoencoderConfig):
    """Params: per-stage encoder convs (stride 2) and the mirror decoder
    transposed convs (stride 2, output_padding 1 -> exact 2x upsampling
    for even planes)."""
    chans = (cfg.c_in, *cfg.widths)
    ks = jax.random.split(key, 2 * len(cfg.widths))
    enc = [L.init_conv2d(ks[i], chans[i], chans[i + 1], cfg.k, cfg.dtype)
           for i in range(len(cfg.widths))]
    dec = [L.init_conv2d_transpose(ks[len(cfg.widths) + i], chans[i + 1],
                                   chans[i], cfg.k, cfg.dtype)
           for i in reversed(range(len(cfg.widths)))]
    return {"enc": enc, "dec": dec}


def autoencoder_apply(params, x, cfg: AutoencoderConfig, policy=None):
    """x (B, C, H, W) -> reconstruction (B, C, H, W); H, W must be
    divisible by 2**len(widths).  ``policy`` defaults to the config's
    engine policy -- every conv pass (encoder and decoder) dispatches
    through the per-pass engines."""
    policy = policy if policy is not None else cfg.conv_engine_policy
    pad = cfg.k // 2
    h = x
    for p in params["enc"]:
        h = jax.nn.relu(L.conv2d_apply(p, h, stride=2, padding=pad,
                                       policy=policy))
    for i, p in enumerate(params["dec"]):
        h = L.conv2d_transpose_apply(p, h, stride=2, padding=pad,
                                     output_padding=1, policy=policy)
        if i < len(params["dec"]) - 1:
            h = jax.nn.relu(h)
    return h


def autoencoder_loss(params, batch, cfg: AutoencoderConfig):
    """Reconstruction MSE over ``batch["image"]`` -- the ``loss=`` plugin
    for ``make_train_step``."""
    x = batch["image"]
    x_hat = autoencoder_apply(params, x, cfg)
    mse = jnp.mean(jnp.square(x_hat.astype(jnp.float32)
                              - x.astype(jnp.float32)))
    return mse, {"mse": mse, "loss": mse}


def build_model(cfg: ArchConfig) -> Model:
    return Model(
        cfg=cfg,
        init=lambda key: init_params(key, cfg),
        forward=lambda params, batch: forward(params, batch, cfg),
        init_cache=lambda batch, max_len: T.init_cache(cfg, batch, max_len),
        decode_step=lambda params, cache, tok, pos: decode_step(
            params, cache, tok, pos, cfg),
        prefill=lambda params, tokens, max_len: prefill(
            params, tokens, cfg, max_len),
        param_count=count_params,
        active_param_count=lambda p: count_active_params(p, cfg),
    )
