"""Primitive layers: linear / norm / embedding / RoPE / SwiGLU / conv2d.

Functional style: ``init_*`` builds param pytrees (optionally with a stacked
leading layer dim for lax.scan), ``*_apply`` consumes them.  Parameter tree
keys are stable and path-matchable by repro.dist.sharding rules.

Conv layers go through ``repro.core.conv2d`` so their backward pass runs the
BP-im2col engines selected by the per-pass ``policy=`` (usually
``cfg.conv_policy``) rather than XLA's native conv autodiff.  Geometry is a
``ConvSpec`` (built from the loose kwargs when not given).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import conv as C
from repro.core.convspec import ConvSpec, ConvTransposeSpec


def _maybe_stack(shape, L):
    return shape if L is None else (L, *shape)


def init_linear(key, d_in: int, d_out: int, dtype, L=None, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, _maybe_stack((d_in, d_out), L), jnp.float32)
    return {"w": (w * scale).astype(dtype)}


def linear(p, x):
    return x @ p["w"].astype(x.dtype)


def init_rmsnorm(d: int, dtype, L=None):
    return {"scale": jnp.ones(_maybe_stack((d,), L), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_embedding(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"w": w.astype(dtype)}


def embed(p, ids):
    return jnp.take(p["w"], ids, axis=0)


def unembed(p, x):
    """Logits from (tied or dedicated) embedding matrix."""
    return x @ p["w"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Conv (BP-im2col backprop engine)
# ---------------------------------------------------------------------------

def init_conv2d(key, c_in: int, c_out: int, k, dtype, groups: int = 1,
                L=None):
    """OIHW conv kernel; k is an int or (kh, kw).  fan-in init."""
    kh, kw = (k, k) if isinstance(k, int) else k
    assert c_in % groups == 0 and c_out % groups == 0
    fan_in = (c_in // groups) * kh * kw
    w = jax.random.normal(
        key, _maybe_stack((c_out, c_in // groups, kh, kw), L), jnp.float32)
    return {"w": (w * fan_in ** -0.5).astype(dtype)}


def conv2d_apply(p, x, *, spec: ConvSpec | None = None, policy=None,
                 stride=None, padding=None, dilation=None, groups=None,
                 mode=None):
    """x (B, C, H, W) -> (B, N, H_o, W_o) through the selected engines.

    ``spec`` carries the full geometry; without it the loose kwargs build
    one (padding: int, (ph, pw), or ((top, bottom), (left, right))).
    ``policy`` selects the backprop engine per pass (EnginePolicy, policy
    string, engine name, or None for auto); ``mode=`` is the deprecated
    uniform spelling.
    """
    loose = {k: v for k, v in (("stride", stride), ("padding", padding),
                               ("dilation", dilation), ("groups", groups))
             if v is not None}
    if spec is None:
        spec = ConvSpec.make(**loose)
    elif loose:
        raise TypeError(f"geometry given both in spec= and as kwargs "
                        f"{sorted(loose)}; put it all in the spec")
    return C.conv2d(x, p["w"].astype(x.dtype), spec, policy, mode=mode)


def init_conv2d_transpose(key, c_in: int, c_out: int, k, dtype,
                          groups: int = 1, L=None):
    """Transposed-conv kernel ``(C_in, C_out/g, kh, kw)`` (the mirror
    conv's OIHW weight with in/out roles swapped); k is an int or
    (kh, kw).  fan-in init over the taps feeding one output pixel."""
    kh, kw = (k, k) if isinstance(k, int) else k
    assert c_in % groups == 0 and c_out % groups == 0
    fan_in = (c_in // groups) * kh * kw
    w = jax.random.normal(
        key, _maybe_stack((c_in, c_out // groups, kh, kw), L), jnp.float32)
    return {"w": (w * fan_in ** -0.5).astype(dtype)}


def conv2d_transpose_apply(p, x, *, spec: ConvTransposeSpec | None = None,
                           policy=None, stride=None, padding=None,
                           output_padding=None, dilation=None, groups=None):
    """x (B, C_in, H, W) -> (B, C_out, H_out, W_out) transposed conv
    through the selected engines (decoders / upsampling heads).

    ``spec`` carries the full geometry; without it the loose kwargs build
    one.  ``policy`` selects the engine per pass exactly as for
    :func:`conv2d_apply` -- the transposed forward rides the input-grad
    (tap-GEMM) machinery, its VJP the regular-conv engines.
    """
    loose = {k: v for k, v in (("stride", stride), ("padding", padding),
                               ("output_padding", output_padding),
                               ("dilation", dilation), ("groups", groups))
             if v is not None}
    if spec is None:
        spec = ConvTransposeSpec.make(**loose)
    elif loose:
        raise TypeError(f"geometry given both in spec= and as kwargs "
                        f"{sorted(loose)}; put it all in the spec")
    return C.conv2d_transpose(x, p["w"].astype(x.dtype), spec,
                              policy=policy)


def init_conv1d(key, c_in: int, c_out: int, k: int, dtype, groups: int = 1,
                L=None):
    w = jax.random.normal(
        key, _maybe_stack((c_out, c_in // groups, k), L), jnp.float32)
    fan_in = (c_in // groups) * k
    return {"w": (w * fan_in ** -0.5).astype(dtype)}


def conv1d_apply(p, x, *, stride: int = 1, padding=0, causal: bool = False,
                 policy=None, groups: int = 1, mode=None):
    """x (B, C, L) -> (B, N, L_o); causal=True left-pads K-1 (asymmetric)."""
    w = p["w"].astype(x.dtype)
    if causal:
        return C.conv1d_causal(x, w, policy, groups, mode=mode)
    return C.conv1d(x, w, stride, padding, policy, groups, mode=mode)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, positions: jax.Array):
    """positions (L,) -> (L, head_dim/2) angles."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[:, None] * inv[None, :]


def apply_rope(x: jax.Array, angles: jax.Array):
    """x (..., L, H, D) with angles (L, D/2): rotate pairs (interleaved halves)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, dtype, L=None):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": init_linear(k1, d, f, dtype, L),        # up
        "wg": init_linear(k2, d, f, dtype, L),        # gate
        "wo": init_linear(k3, f, d, dtype, L, scale=f ** -0.5),
    }


def mlp(p, x):
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))
