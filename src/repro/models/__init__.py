from repro.models.model import build_model, init_params, forward, decode_step

__all__ = ["build_model", "init_params", "forward", "decode_step"]
