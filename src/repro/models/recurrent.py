"""RecurrentGemma / Griffin (arXiv:2402.19427) recurrent block.

Structure per recurrent block:
    branch 1: W_x -> temporal Conv1D (width 4, causal, BP-im2col engine)
              -> RG-LRU
    branch 2: W_gate -> GeLU
    merge   : elementwise product -> W_out

RG-LRU recurrence (diagonal, so associative-scan friendly):
    r_t = sigmoid(W_r x_t),  i_t = sigmoid(W_i x_t)
    a_t = exp(-c * softplus(lambda) * r_t)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import depthwise_causal_conv1d
from repro.models import layers as L

RG_C = 8.0


def rec_width(cfg: ArchConfig) -> int:
    return cfg.rglru_width or cfg.d_model


def init_recurrent(key, cfg: ArchConfig, nl=None):
    d, w = cfg.d_model, rec_width(cfg)
    ks = jax.random.split(key, 6)
    shape = lambda *s: s if nl is None else (nl, *s)
    return {
        "wx": L.init_linear(ks[0], d, w, cfg.dtype, nl),
        "wgate": L.init_linear(ks[1], d, w, cfg.dtype, nl),
        "conv_w": {"w": (jax.random.normal(ks[2], shape(cfg.rglru_conv, w),
                                           jnp.float32) * 0.2).astype(cfg.dtype)},
        "wr": L.init_linear(ks[3], w, w, cfg.dtype, nl),
        "wi": L.init_linear(ks[4], w, w, cfg.dtype, nl),
        "lam": {"w": jnp.full(shape(w), 0.65, jnp.float32)},  # softplus^-1 spread
        "wout": L.init_linear(ks[5], w, d, cfg.dtype, nl, scale=w ** -0.5),
    }


def _rglru_scan(x, r, i, lam):
    """Full-sequence RG-LRU via associative scan.  x,r,i (B,L,W)."""
    log_a = -RG_C * jax.nn.softplus(lam)[None, None, :] * r      # (B,L,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_seq, h_seq = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h_seq


def recurrent_block(p, x, cfg: ArchConfig):
    """x (B, L, D) -> (B, L, D), full-sequence."""
    xb = L.linear(p["wx"], x)                                    # (B,L,W)
    xb = depthwise_causal_conv1d(xb, p["conv_w"]["w"],
                                 policy=cfg.conv_engine_policy)
    r = jax.nn.sigmoid(L.linear(p["wr"], xb).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wi"], xb).astype(jnp.float32))
    h = _rglru_scan(xb.astype(jnp.float32), r, i, p["lam"]["w"])
    gate = jax.nn.gelu(L.linear(p["wgate"], x))
    return L.linear(p["wout"], (h.astype(x.dtype) * gate))


def recurrent_init_state(cfg: ArchConfig, batch: int, nl: int):
    w = rec_width(cfg)
    return {
        "h": jnp.zeros((nl, batch, w), jnp.float32),
        "conv": jnp.zeros((nl, batch, cfg.rglru_conv - 1, w), cfg.adtype),
    }


def recurrent_decode(p, x, h_state, conv_state, cfg: ArchConfig):
    """Single-token step.  x (B,1,D)."""
    xb = L.linear(p["wx"], x)[:, 0]                              # (B,W)
    hist = jnp.concatenate(
        [conv_state, xb[:, None, :].astype(conv_state.dtype)], axis=1)
    w = p["conv_w"]["w"].astype(hist.dtype)
    xc = jnp.einsum("bkc,kc->bc", hist, w)
    new_conv_state = hist[:, 1:]
    r = jax.nn.sigmoid(L.linear(p["wr"], xc).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wi"], xc).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"]["w"])[None] * r
    a = jnp.exp(log_a)
    new_h = a * h_state + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) \
        * (i * xc.astype(jnp.float32))
    gate = jax.nn.gelu(L.linear(p["wgate"], x))[:, 0]
    out = L.linear(p["wout"], new_h.astype(x.dtype) * gate)
    return out[:, None, :], new_h, new_conv_state
