"""Attention variants: GQA (causal / bidirectional / local-window) and MLA.

All variants support three entry points used by the launcher:
  * ``*_train``   -- full-sequence forward (training & prefill)
  * ``*_decode``  -- single-token step against a KV cache
Cache layouts are plain pytrees so they shard with NamedSharding like params.

The MLA decode path uses weight absorption: scores are computed directly in
the latent space (c_kv of rank ``kv_lora_rank`` + rope keys), so the cache
holds only the compressed latents -- the published memory advantage of MLA.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.config import config
from repro.models import layers as L


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, nl=None):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": L.init_linear(k1, d, h * dh, cfg.dtype, nl),
        "wk": L.init_linear(k2, d, hk * dh, cfg.dtype, nl),
        "wv": L.init_linear(k3, d, hk * dh, cfg.dtype, nl),
        "wo": L.init_linear(k4, h * dh, d, cfg.dtype, nl, scale=(h * dh) ** -0.5),
    }


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


BLOCK_K = 512


def __getattr__(name):
    # Deprecated alias: the KV-length crossover now lives at
    # repro.config.blockwise_kv_threshold (read per call).
    if name == "BLOCKWISE_KV_THRESHOLD":
        return config.blockwise_kv_threshold
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _sdpa_dense(q, k, v, *, causal, window, q_offset, kv_len, scale):
    b, lq, h, dh = q.shape
    lk, hk = k.shape[1], k.shape[2]
    g = h // hk
    qh = q.reshape(b, lq, hk, g, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qh, k).astype(jnp.float32)
    logits *= scale
    # q_offset / kv_len are scalars under wave batching (one position clock
    # for the whole batch) or per-lane (B,) vectors under continuous
    # batching (every lane sits at its own position); a leading batch dim
    # broadcasts through the (Lq, Lk) mask.
    q_pos = jnp.asarray(q_offset)[..., None, None] + jnp.arange(lq)[:, None]
    k_pos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask = mask & (q_pos >= k_pos)
    if window is not None:
        mask = mask & (q_pos - k_pos < window)
    if kv_len is not None:
        mask = mask & (k_pos < jnp.asarray(kv_len)[..., None, None])
    mask = jnp.broadcast_to(mask, (b, lq, lk))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(b, lq, h, dh)


def _sdpa_blockwise(q, k, v, *, causal, window, q_offset, kv_len, scale):
    """Online-softmax scan over KV blocks: peak memory O(Lq x BLOCK_K)
    instead of O(Lq x Lk).  Same math as _sdpa_dense (flash-style, in XLA)."""
    b, lq, h, dh = q.shape
    lk, hk = k.shape[1], k.shape[2]
    g = h // hk
    bk = BLOCK_K
    pad = (-lk) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    lkp = lk + pad
    nblk = lkp // bk
    qh = (q.reshape(b, lq, hk, g, dh).astype(jnp.float32)) * scale
    kb = k.reshape(b, nblk, bk, hk, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, bk, hk, dh).transpose(1, 0, 2, 3, 4)
    q_pos = q_offset + jnp.arange(lq)

    def body(carry, xs):
        m, l, acc = carry
        kblk, vblk, blk_i = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qh, kblk.astype(jnp.float32))
        k_pos = blk_i * bk + jnp.arange(bk)
        mask = (k_pos[None, :] < lk)
        if kv_len is not None:
            mask &= k_pos[None, :] < kv_len
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vblk.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, lq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hk, g, lq), jnp.float32)
    a0 = jnp.zeros((b, hk, g, lq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, lq, h, dh).astype(q.dtype)


# Perf-iteration knob (§Perf): when True, full-sequence local-window
# attention only touches the KV blocks inside the window instead of scanning
# (and masking) the entire sequence -- an O(L*W) instead of O(L^2) schedule.
WINDOW_SKIP = False


def _sdpa_local_window(q, k, v, *, window: int, scale: float):
    """Causal local-window self-attention that never touches KV outside the
    window.  q/k/v (B, L, *, D) with equal L; q block i of size W attends the
    2W keys [ (i-1)W, (i+1)W ), masked to the exact window."""
    b, l, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    w = window
    pad = (-l) % w
    lp = l + pad
    nq = lp // w
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # keys get a leading extra W block of zeros so block i-1 always exists
    kp = jnp.pad(k, ((0, 0), (w, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (w, pad), (0, 0), (0, 0)))
    qb = qp.reshape(b, nq, w, hk, g, dh).astype(jnp.float32) * scale
    kb = kp.reshape(b, nq + 1, w, hk, dh)
    vb = vp.reshape(b, nq + 1, w, hk, dh)
    k2 = jnp.concatenate([kb[:, :-1], kb[:, 1:]], axis=2)   # (B,nq,2W,Hk,D)
    v2 = jnp.concatenate([vb[:, :-1], vb[:, 1:]], axis=2)
    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2.astype(jnp.float32))
    # q block i, local qi: absolute q = i*W + qi; key slab index s covers
    # absolute keys (i-1)*W + s.  Causal window: 0 <= q_abs - k_abs < window;
    # plus k_abs >= 0 (the leading zero block) and k_abs < l (tail pad).
    qi = jnp.arange(w)
    si = jnp.arange(2 * w)
    d = w + qi[:, None] - si[None, :]                       # (W, 2W)
    base = (d >= 0) & (d < window)
    k_abs = (jnp.arange(nq)[:, None] - 1) * w + si[None, :]  # (nq, 2W)
    in_range = (k_abs >= 0) & (k_abs < l)
    mask = base[None] & in_range[:, None, :]                # (nq, W, 2W)
    logits = jnp.where(mask[None, :, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bnkgqs,bnskd->bnqkgd", p.astype(v.dtype), v2)
    o = o.reshape(b, lp, h, dh)[:, :l]
    return o


def _sdpa(q, k, v, *, causal: bool, window: int | None, q_offset: int = 0,
          kv_len: jax.Array | None = None, scale: float | None = None):
    """q (B,Lq,H,D), k/v (B,Lk,Hk,D); returns (B,Lq,H,D).

    GQA: query head h attends kv head h // (H/Hk).  window is a local
    attention window (RecurrentGemma); kv_len masks cache positions >= len.
    Long KV switches to the blockwise online-softmax path (flash-style).
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    if (WINDOW_SKIP and window is not None and causal
            and q.shape[1] == k.shape[1] and q.shape[1] >= 2 * window
            and kv_len is None and q_offset == 0):
        return _sdpa_local_window(q, k, v, window=window, scale=scale)
    if k.shape[1] > config.blockwise_kv_threshold and q.shape[1] > 1:
        return _sdpa_blockwise(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, kv_len=kv_len, scale=scale)
    return _sdpa_dense(q, k, v, causal=causal, window=window,
                       q_offset=q_offset, kv_len=kv_len, scale=scale)


def gqa_train(p, x, cfg: ArchConfig, *, window=None, positions=None):
    b, l, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = positions if positions is not None else jnp.arange(l)
    ang = L.rope_freqs(dh, cfg.rope_theta, positions)
    q = L.apply_rope(_split_heads(L.linear(p["wq"], x), h, dh), ang)
    k = L.apply_rope(_split_heads(L.linear(p["wk"], x), hk, dh), ang)
    v = _split_heads(L.linear(p["wv"], x), hk, dh)
    causal = not cfg.is_encoder_only
    o = _sdpa(q, k, v, causal=causal, window=window)
    return L.linear(p["wo"], o.reshape(b, l, h * dh))


def gqa_init_cache(cfg: ArchConfig, batch: int, max_len: int, nl: int):
    dh, hk = cfg.head_dim, cfg.n_kv_heads
    shape = (nl, batch, max_len, hk, dh)
    return {"k": jnp.zeros(shape, cfg.adtype),
            "v": jnp.zeros(shape, cfg.adtype)}


def gqa_decode(p, x, cache_k, cache_v, pos, cfg: ArchConfig, *, window=None):
    """x (B,1,D); cache_k/v (B,Lmax,Hk,Dh) -> (out, k, v).

    ``pos`` is a scalar (wave batching: one position clock for the whole
    batch, cache update lowers to one dynamic_update_slice) or a per-lane
    (B,) vector (continuous batching: every lane writes its own slot --
    one scatter, the cost the slotted engine pays for mid-stream
    admission)."""
    b, _, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pos = jnp.asarray(pos)
    per_lane = pos.ndim == 1
    ang = L.rope_freqs(dh, cfg.rope_theta,
                       (pos if per_lane else pos[None]).astype(jnp.float32))
    if per_lane:
        ang = ang[:, None, :]                 # (B, 1, Dh/2): one pos per lane
    q = L.apply_rope(_split_heads(L.linear(p["wq"], x), h, dh), ang)
    k = L.apply_rope(_split_heads(L.linear(p["wk"], x), hk, dh), ang)
    v = _split_heads(L.linear(p["wv"], x), hk, dh)
    if per_lane:
        lanes = jnp.arange(b)
        cache_k = cache_k.at[lanes, pos].set(k[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[lanes, pos].set(v[:, 0].astype(cache_v.dtype))
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), pos, axis=1)
    o = _sdpa(q, cache_k, cache_v, causal=False, window=window,
              q_offset=pos, kv_len=pos + 1)
    if window is not None:
        pass  # window mask applied inside _sdpa via q_offset
    return L.linear(p["wo"], o.reshape(b, 1, h * dh)), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, nl=None):
    d = cfg.d_model
    h = cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": L.init_linear(ks[0], d, qr, cfg.dtype, nl),
        "q_norm": L.init_rmsnorm(qr, cfg.dtype, nl),
        "wq_b": L.init_linear(ks[1], qr, h * (dn + dr), cfg.dtype, nl),
        "wkv_a": L.init_linear(ks[2], d, kvr + dr, cfg.dtype, nl),
        "kv_norm": L.init_rmsnorm(kvr, cfg.dtype, nl),
        "wkv_b": L.init_linear(ks[3], kvr, h * (dn + dv), cfg.dtype, nl),
        "wo": L.init_linear(ks[4], h * dv, d, cfg.dtype, nl,
                            scale=(h * dv) ** -0.5),
    }


def _mla_qkv(p, x, cfg: ArchConfig, positions):
    b, l, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ang = L.rope_freqs(dr, cfg.rope_theta, positions)
    q = L.linear(p["wq_b"], L.rmsnorm(p["q_norm"], L.linear(p["wq_a"], x)))
    q = q.reshape(b, l, h, dn + dr)
    q_nope, q_rope = q[..., :dn], L.apply_rope(q[..., dn:], ang)
    kv = L.linear(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_rope = L.apply_rope(kv[..., None, cfg.kv_lora_rank:], ang)  # (B,L,1,dr)
    kvu = L.linear(p["wkv_b"], c_kv).reshape(b, l, h, dn + dv)
    k_nope, v = kvu[..., :dn], kvu[..., dn:]
    return q_nope, q_rope, k_nope, k_rope, v, c_kv


def mla_train(p, x, cfg: ArchConfig, *, positions=None):
    b, l, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = positions if positions is not None else jnp.arange(l)
    q_nope, q_rope, k_nope, k_rope, v, _ = _mla_qkv(p, x, cfg, positions)
    # Fold the shared rope key into per-head features so the common (block-
    # wise) SDPA core applies: q_cat/k_cat have head dim dn + dr.
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, l, h, dr))], axis=-1)
    # v head dim dv may differ from dn+dr; pad v for the shared core and crop.
    o = _sdpa(q_cat, k_cat,
              jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
              if dv < dn + dr else v,
              causal=True, window=None, scale=(dn + dr) ** -0.5)
    o = o[..., :dv]
    return L.linear(p["wo"], o.reshape(b, l, h * dv))


def mla_init_cache(cfg: ArchConfig, batch: int, max_len: int, nl: int):
    return {
        "c_kv": jnp.zeros((nl, batch, max_len, cfg.kv_lora_rank), cfg.adtype),
        "k_rope": jnp.zeros((nl, batch, max_len, cfg.qk_rope_head_dim),
                            cfg.adtype),
    }


def mla_decode(p, x, c_kv_cache, k_rope_cache, pos, cfg: ArchConfig):
    """Absorbed-weight MLA decode: attention runs in the latent space.

    x (B,1,D); c_kv_cache (B,Lmax,kvr); k_rope_cache (B,Lmax,dr); pos is
    a scalar (wave batching) or a per-lane (B,) vector (continuous
    batching -- see :func:`gqa_decode`).
    """
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    pos = jnp.asarray(pos)
    per_lane = pos.ndim == 1
    ang = L.rope_freqs(dr, cfg.rope_theta,
                       (pos if per_lane else pos[None]).astype(jnp.float32))
    if per_lane:
        ang = ang[:, None, :]                 # (B, 1, dr/2): one pos per lane
    q = L.linear(p["wq_b"], L.rmsnorm(p["q_norm"], L.linear(p["wq_a"], x)))
    q = q.reshape(b, 1, h, dn + dr)
    q_nope, q_rope = q[..., :dn], L.apply_rope(q[..., dn:], ang)
    kv = L.linear(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv[..., :kvr])                   # (B,1,kvr)
    k_rope = L.apply_rope(kv[..., None, kvr:], ang)[:, :, 0]        # (B,1,dr)
    if per_lane:
        lanes = jnp.arange(b)
        c_kv_cache = c_kv_cache.at[lanes, pos].set(
            c_kv[:, 0].astype(c_kv_cache.dtype))
        k_rope_cache = k_rope_cache.at[lanes, pos].set(
            k_rope[:, 0].astype(k_rope_cache.dtype))
    else:
        c_kv_cache = jax.lax.dynamic_update_slice_in_dim(
            c_kv_cache, c_kv.astype(c_kv_cache.dtype), pos, axis=1)
        k_rope_cache = jax.lax.dynamic_update_slice_in_dim(
            k_rope_cache, k_rope.astype(k_rope_cache.dtype), pos, axis=1)
    # Absorb wkv_b's key half into the query: q_lat (B,1,H,kvr)
    wkv_b = p["wkv_b"]["w"].reshape(kvr, h, dn + dv)
    w_k = wkv_b[..., :dn]                                           # (kvr,H,dn)
    w_v = wkv_b[..., dn:]                                           # (kvr,H,dv)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k.astype(x.dtype))
    scale = (dn + dr) ** -0.5
    logits = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv_cache.astype(x.dtype))
              + jnp.einsum("bqhd,bsd->bhqs", q_rope,
                           k_rope_cache.astype(x.dtype))) * scale
    k_pos = jnp.arange(c_kv_cache.shape[1])[None, None, None, :]
    lim = pos[:, None, None, None] if per_lane else pos
    logits = jnp.where(k_pos <= lim, logits.astype(jnp.float32), -1e30)
    pr = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", pr, c_kv_cache.astype(x.dtype))
    o = jnp.einsum("bqhr,rhd->bqhd", o_lat, w_v.astype(x.dtype))
    out = L.linear(p["wo"], o.reshape(b, 1, h * dv))
    return out, c_kv_cache, k_rope_cache
