"""Mixture-of-Experts with GShard-style grouped capacity dispatch.

Tokens are partitioned into groups of ~``MOE_GROUP_TOKENS``; each group
routes independently with capacity ``S * top_k * capacity_factor / E``.
Grouping bounds the one-hot dispatch tensor to (G, S, E, C) with small S and
C, which under GSPMD shards as G->data, E->model -- the standard production
MoE lowering (GShard/GLaM).  Compute is proportional to ACTIVE parameters
(top_k * cf), so roofline terms reflect 6*N_active*D accounting.

Expert parallelism folds into the mesh "model" axis via the (E, ., .) expert
weight sharding (see dist.sharding); dispatch/combine einsums then induce the
all-to-all-like collectives visible in the dry-run HLO.

Aux losses: Switch-style load balance + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

MOE_GROUP_TOKENS = 512


def init_moe(key, cfg: ArchConfig, nl=None):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    shape = lambda *s: s if nl is None else (nl, *s)
    p = {
        "router": L.init_linear(ks[0], d, e, jnp.float32, nl),
        "wi": {"w": (jax.random.normal(ks[1], shape(e, d, f), jnp.float32)
                     * d ** -0.5).astype(cfg.dtype)},
        "wg": {"w": (jax.random.normal(ks[2], shape(e, d, f), jnp.float32)
                     * d ** -0.5).astype(cfg.dtype)},
        "wo": {"w": (jax.random.normal(ks[3], shape(e, f, d), jnp.float32)
                     * f ** -0.5).astype(cfg.dtype)},
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, cfg.moe_d_ff * cfg.n_shared_experts,
                                 cfg.dtype, nl)
    return p


def _group(t: int) -> int:
    """Largest group count G dividing t with t/G <= MOE_GROUP_TOKENS."""
    g = max(1, t // MOE_GROUP_TOKENS)
    while t % g:
        g -= 1
    return g


def moe_apply(p, x, cfg: ArchConfig, capacity: int | None = None):
    """x (B, L, D) -> (out (B, L, D), aux dict)."""
    b, l, d = x.shape
    t = b * l
    e, k = cfg.n_experts, cfg.moe_top_k
    g = _group(t)
    s = t // g
    cap = capacity or max(1, int(s * k * cfg.capacity_factor / e))
    cap = min(cap, s)
    xg = x.reshape(g, s, d)

    logits = L.linear(p["router"], xg.astype(jnp.float32))        # (G,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                 # (G,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Capacity bookkeeping: choice waves queue sequentially per expert.
    combine = jnp.zeros((g, s, e, cap), jnp.float32)
    prior = jnp.zeros((g, e), jnp.int32)
    for choice in range(k):
        oh = jax.nn.one_hot(gate_idx[..., choice], e, dtype=jnp.int32)
        pos = jnp.cumsum(oh, axis=1) - 1 + prior[:, None, :]       # (G,S,E)
        prior = prior + oh.sum(1)
        keep = (pos < cap) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                                dtype=jnp.float32)[..., :cap]      # (G,S,E,C)
        combine = combine + pos_oh * gate_vals[..., choice][..., None, None]
    dispatch = (combine > 0).astype(x.dtype)                       # (G,S,E,C)

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)                # (G,E,C,D)
    wi = p["wi"]["w"].astype(x.dtype)
    wg_ = p["wg"]["w"].astype(x.dtype)
    wo = p["wo"]["w"].astype(x.dtype)
    hi = jnp.einsum("gecd,edf->gecf", xe, wi)
    hg = jnp.einsum("gecd,edf->gecf", xe, wg_)
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(hg) * hi, wo)
    out = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)

    if "shared" in p:
        out = out + L.mlp(p["shared"], xg)

    frac_tokens = jax.nn.one_hot(gate_idx[..., 0], e).mean((0, 1))
    mean_prob = probs.mean((0, 1))
    lb_loss = e * jnp.sum(frac_tokens * mean_prob)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.reshape(b, l, d), {"moe_lb": lb_loss, "moe_z": z_loss}
