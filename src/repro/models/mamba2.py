"""Mamba2 (SSD, arXiv:2405.21060) block: chunked state-space duality.

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
(attention-like) term + across-chunk linear state recurrence, so memory is
O(L*Q + L/Q * state) instead of O(L * state) for the naive scan.  Decode is
the O(1) recurrent update.

The causal depthwise Conv1D (width ``ssm_conv``) routes through
repro.core.depthwise_causal_conv1d -- the layer that hosts the paper's
BP-im2col engine inside this architecture.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import depthwise_causal_conv1d
from repro.core.config import config
from repro.models import layers as L


def __getattr__(name):
    # Deprecated alias for the pre-config module constant; the SSD chunk
    # length now lives at repro.config.ssd_chunk (read per call, so tests
    # can override it without reload tricks).
    if name == "CHUNK":
        return config.ssd_chunk
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def d_inner(cfg: ArchConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads(cfg: ArchConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(key, cfg: ArchConfig, nl=None):
    di, h, ds = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    ks = jax.random.split(key, 4)
    shape = lambda *s: s if nl is None else (nl, *s)
    # in_proj packs [z, x, B, C, dt]
    proj_out = 2 * di + 2 * ds + h
    return {
        "in_proj": L.init_linear(ks[0], cfg.d_model, proj_out, cfg.dtype, nl),
        "conv_w": {"w": (jax.random.normal(ks[1], shape(cfg.ssm_conv,
                                                        di + 2 * ds),
                                           jnp.float32) * 0.2).astype(cfg.dtype)},
        "a_log": {"w": jnp.zeros(shape(h), jnp.float32)},
        "dt_bias": {"w": jnp.zeros(shape(h), jnp.float32)},
        "d_skip": {"w": jnp.ones(shape(h), jnp.float32)},
        "norm": L.init_rmsnorm(di, cfg.dtype, nl),
        "out_proj": L.init_linear(ks[2], di, cfg.d_model, cfg.dtype, nl,
                                  scale=di ** -0.5),
    }


def _ssd_chunked(xh, dt, a_log, B, C):
    """Chunked SSD.

    xh (B,L,H,P)  dt (B,L,H)  a_log (H,)  B,C (B,L,S)  ->  y (B,L,H,P)
    """
    b, l, h, p = xh.shape
    s = B.shape[-1]
    # SSD chunk length: intra-chunk (quadratic) work scales ~Q per token,
    # the inter-chunk state recurrence ~1/Q -- a perf-iteration lever.
    q = min(config.ssd_chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    nc = l // q

    la = dt * (-jnp.exp(a_log))[None, None, :]              # log a_t  (B,L,H)
    la = la.reshape(b, nc, q, h)
    dt_r = dt.reshape(b, nc, q, h)
    xr = xh.reshape(b, nc, q, h, p)
    Br = B.reshape(b, nc, q, s)
    Cr = C.reshape(b, nc, q, s)
    cum = jnp.cumsum(la, axis=2)                            # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within chunk) ----
    cb = jnp.einsum("bnis,bnjs->bnij", Cr, Br)              # (B,nc,Q,Q)
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    att = jnp.where(mask[None, None, :, :, None],
                    jnp.exp(decay), 0.0)
    att = att * cb[..., None] * dt_r[:, :, None, :, :]      # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", att.astype(xr.dtype), xr)

    # ---- chunk states & inter-chunk recurrence ----
    last = cum[:, :, -1:, :]                                # (B,nc,1,H)
    state_w = jnp.exp(last - cum) * dt_r                    # (B,nc,Q,H)
    states = jnp.einsum("bnqs,bnqh,bnqhp->bnhps",
                        Br, state_w.astype(xr.dtype), xr)   # (B,nc,H,P,S)
    chunk_decay = jnp.exp(last[:, :, 0, :])                 # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp                                       # (B,H,P,S),(B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry                                   # emit PREVIOUS

    init = jnp.zeros((b, h, p, s), xr.dtype)
    _, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4),
         chunk_decay.transpose(1, 0, 2).astype(xr.dtype)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)      # (B,nc,H,P,S)

    y_inter = jnp.einsum("bnqs,bnqh,bnhps->bnqhp",
                         Cr, jnp.exp(cum).astype(xr.dtype), prev_states)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y


def mamba2_block(p, x, cfg: ArchConfig):
    """Full-sequence forward. x (B, L, D) -> (B, L, D)."""
    b, l, d = x.shape
    di, h, ds, dh = d_inner(cfg), n_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = L.linear(p["in_proj"], x)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)        # (B,L,di+2S)
    conv_out = depthwise_causal_conv1d(conv_in, p["conv_w"]["w"],
                                       policy=cfg.conv_engine_policy)
    conv_out = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(conv_out, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"]["w"][None, None, :])
    xh = xs.reshape(b, l, h, dh)
    y = _ssd_chunked(xh, dt, p["a_log"]["w"], Bc.astype(xh.dtype),
                     Cc.astype(xh.dtype))
    y = y + xh * p["d_skip"]["w"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(b, l, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.linear(p["out_proj"], y)


def mamba2_init_state(cfg: ArchConfig, batch: int, nl: int):
    di, h, ds, dh = d_inner(cfg), n_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((nl, batch, h, dh, ds), cfg.adtype),
        "conv": jnp.zeros((nl, batch, cfg.ssm_conv - 1, di + 2 * ds),
                          cfg.adtype),
    }


def mamba2_decode(p, x, ssm_state, conv_state, cfg: ArchConfig):
    """Single-token recurrent step.  x (B,1,D)."""
    b = x.shape[0]
    di, h, ds, dh = d_inner(cfg), n_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim
    zxbcdt = L.linear(p["in_proj"], x)[:, 0]                # (B, proj)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1)
    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)        # (B, di+2S)
    hist = jnp.concatenate([conv_state,
                            conv_in[:, None, :].astype(conv_state.dtype)],
                           axis=1)                          # (B, K, ch)
    w = p["conv_w"]["w"].astype(hist.dtype)                 # (K, ch)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", hist, w))
    new_conv_state = hist[:, 1:]
    xs, Bc, Cc = jnp.split(conv_out, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"]["w"][None])
    a = jnp.exp(dt * (-jnp.exp(p["a_log"]["w"]))[None])     # (B,H)
    xh = xs.reshape(b, h, dh)
    upd = jnp.einsum("bh,bhp,bs->bhps", dt.astype(xh.dtype), xh,
                     Bc.astype(xh.dtype))
    new_ssm = ssm_state * a[:, :, None, None].astype(ssm_state.dtype) \
        + upd.astype(ssm_state.dtype)
    y = jnp.einsum("bhps,bs->bhp", new_ssm.astype(xh.dtype),
                   Cc.astype(xh.dtype))
    y = y + xh * p["d_skip"]["w"][None, :, None].astype(xh.dtype)
    y = y.reshape(b, di)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = L.linear(p["out_proj"], y)[:, None, :]
    return out, new_ssm, new_conv_state
