"""Block assembly: stacked-parameter blocks executed under jax.lax.scan.

All layers of a kind share one stacked param pytree (leading dim = #layers),
so an 80-layer model lowers as ONE scanned block body -- compile time and HLO
size stay flat in depth, which matters for the 40-cell x 2-mesh dry-run.

Families:
  dense / vlm / audio : single stack of attention blocks
  moe                 : dense stack (first_dense_layers) + MoE stack
  hybrid              : stack of (rec, rec, attn) super-blocks + remainder recs
  ssm                 : stack of mamba2 blocks

Decode variants scan the same stacks with per-layer cache slices as scan xs.
"""

from __future__ import annotations

import jax

from repro.configs.base import ArchConfig
from repro.core.config import config
from repro.dist.constraints import constrain_batch
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.models import recurrent as R


def __getattr__(name):
    # Deprecated alias: the layer-scan unroll factor now lives at
    # repro.config.scan_unroll (roofline dry-runs set 9999 so XLA's
    # cost_analysis sees every layer -- while-loop bodies are not
    # multiplied by trip count).
    if name == "SCAN_UNROLL":
        return config.scan_unroll
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


# ---------------------------------------------------------------------------
# Per-kind block init
# ---------------------------------------------------------------------------

def init_attn_block(key, cfg: ArchConfig, nl: int, use_moe: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    attn = (A.init_mla(k1, cfg, nl) if cfg.use_mla
            else A.init_gqa(k1, cfg, nl))
    p = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.dtype, nl),
         "attn": attn,
         "ln2": L.init_rmsnorm(cfg.d_model, cfg.dtype, nl)}
    if use_moe:
        p["moe"] = MOE.init_moe(k2, cfg, nl)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dtype, nl)
    return p


def init_rec_block(key, cfg: ArchConfig, nl: int):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.dtype, nl),
            "rec": R.init_recurrent(k1, cfg, nl),
            "ln2": L.init_rmsnorm(cfg.d_model, cfg.dtype, nl),
            "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.dtype, nl)}


def init_ssm_block(key, cfg: ArchConfig, nl: int):
    return {"ln": L.init_rmsnorm(cfg.d_model, cfg.dtype, nl),
            "ssm": M.init_mamba2(key, cfg, nl)}


# ---------------------------------------------------------------------------
# Per-kind block apply (single layer; params already sliced by scan)
# ---------------------------------------------------------------------------

def attn_block(p, x, cfg: ArchConfig, *, use_moe: bool, window=None):
    x = constrain_batch(x)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        x = x + A.mla_train(p["attn"], h, cfg)
    else:
        x = x + A.gqa_train(p["attn"], h, cfg, window=window)
    x = constrain_batch(x)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, aux = MOE.moe_apply(p["moe"], h, cfg)
        return constrain_batch(x + y), aux
    return constrain_batch(x + L.mlp(p["mlp"], h)), {}


def rec_block(p, x, cfg: ArchConfig):
    x = constrain_batch(x)
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    x = constrain_batch(x + R.recurrent_block(p["rec"], h, cfg))
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return constrain_batch(x + L.mlp(p["mlp"], h))


def ssm_block(p, x, cfg: ArchConfig):
    x = constrain_batch(x)
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    return constrain_batch(x + M.mamba2_block(p["ssm"], h, cfg))


def _maybe_remat(fn, cfg: ArchConfig):
    # config.remat overrides the per-arch policy (perf-iteration lever,
    # §Perf): "none" drops per-block rematerialization (recompute flops
    # saved, activation memory paid), "block" forces it.
    policy = cfg.remat if config.remat is None else config.remat
    return jax.checkpoint(fn) if policy == "block" else fn


def _scan_stack(body, stacked_params, x):
    """body(params_slice, x) -> (x, aux); aux accumulated (summed)."""
    def step(carry, pslice):
        y, aux = body(pslice, carry)
        return y, aux

    nl = jax.tree.leaves(stacked_params)[0].shape[0]
    x, auxs = jax.lax.scan(step, x, stacked_params,
                           unroll=min(config.scan_unroll, nl))
    aux = {k: v.sum() for k, v in auxs.items()} if auxs else {}
    return x, aux


# ---------------------------------------------------------------------------
# Full-stack forward per family
# ---------------------------------------------------------------------------

def init_stacks(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    if cfg.family in ("dense", "vlm", "audio"):
        return {"blocks": init_attn_block(ks[0], cfg, cfg.n_layers, False)}
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        out = {}
        if nd:
            out["blocks_dense"] = init_attn_block(ks[0], cfg, nd, False)
        out["blocks_moe"] = init_attn_block(ks[1], cfg, cfg.n_layers - nd, True)
        return out
    if cfg.family == "hybrid":
        period = len(cfg.layer_pattern)
        n_super = cfg.n_layers // period
        n_extra = cfg.n_layers - n_super * period
        out = {"super": {
            "rec1": init_rec_block(ks[0], cfg, n_super),
            "rec2": init_rec_block(ks[1], cfg, n_super),
            "attn": init_attn_block(ks[2], cfg, n_super, False),
        }}
        if n_extra:
            out["extra"] = init_rec_block(ks[3], cfg, n_extra)
        return out
    if cfg.family == "ssm":
        return {"blocks": init_ssm_block(ks[0], cfg, cfg.n_layers)}
    raise ValueError(cfg.family)


def forward_stacks(params, x, cfg: ArchConfig):
    """x (B, L, D) -> (x, aux) through all blocks."""
    aux = {}
    if cfg.family in ("dense", "vlm", "audio"):
        body = _maybe_remat(
            lambda p, h: attn_block(p, h, cfg, use_moe=False), cfg)
        x, aux = _scan_stack(body, params["blocks"], x)
    elif cfg.family == "moe":
        if "blocks_dense" in params:
            body = _maybe_remat(
                lambda p, h: attn_block(p, h, cfg, use_moe=False), cfg)
            x, _ = _scan_stack(body, params["blocks_dense"], x)
        body = _maybe_remat(
            lambda p, h: attn_block(p, h, cfg, use_moe=True), cfg)
        x, aux = _scan_stack(body, params["blocks_moe"], x)
    elif cfg.family == "hybrid":
        def super_block(p, h):
            h = rec_block(p["rec1"], h, cfg)
            h = rec_block(p["rec2"], h, cfg)
            h, _ = attn_block(p["attn"], h, cfg, use_moe=False,
                              window=cfg.local_window)
            return h, {}
        x, _ = _scan_stack(_maybe_remat(super_block, cfg), params["super"], x)
        if "extra" in params:
            body = _maybe_remat(lambda p, h: (rec_block(p, h, cfg), {}), cfg)
            x, _ = _scan_stack(body, params["extra"], x)
    elif cfg.family == "ssm":
        body = _maybe_remat(lambda p, h: (ssm_block(p, h, cfg), {}), cfg)
        x, _ = _scan_stack(body, params["blocks"], x)
    else:
        raise ValueError(cfg.family)
    return x, aux


# ---------------------------------------------------------------------------
# Decode (single token) through the stacks, cache as scan xs
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    if cfg.family in ("dense", "vlm"):
        return {"blocks": A.gqa_init_cache(cfg, batch, max_len, cfg.n_layers)}
    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        mk = A.mla_init_cache if cfg.use_mla else A.gqa_init_cache
        out = {}
        if nd:
            out["blocks_dense"] = mk(cfg, batch, max_len, nd)
        out["blocks_moe"] = mk(cfg, batch, max_len, cfg.n_layers - nd)
        return out
    if cfg.family == "hybrid":
        period = len(cfg.layer_pattern)
        n_super = cfg.n_layers // period
        n_extra = cfg.n_layers - n_super * period
        cache_len = min(max_len, cfg.local_window or max_len)
        out = {"super": {
            "rec1": R.recurrent_init_state(cfg, batch, n_super),
            "rec2": R.recurrent_init_state(cfg, batch, n_super),
            "attn": A.gqa_init_cache(cfg, batch, max_len, n_super),
        }}
        if n_extra:
            out["extra"] = R.recurrent_init_state(cfg, batch, n_extra)
        return out
    if cfg.family == "ssm":
        return {"blocks": M.mamba2_init_state(cfg, batch, cfg.n_layers)}
    raise ValueError(cfg.family)


def _scan_decode(body, stacked_params, cache, x):
    """body(pslice, cache_slice, x) -> (x, new_cache_slice)."""
    def step(carry, xs):
        pslice, cslice = xs
        y, new_c = body(pslice, cslice, carry)
        return y, new_c

    nl = jax.tree.leaves(stacked_params)[0].shape[0]
    return jax.lax.scan(step, x, (stacked_params, cache),
                        unroll=min(config.scan_unroll, nl))


def decode_stacks(params, cache, x, pos, cfg: ArchConfig):
    """x (B,1,D), pos scalar int -> (x, new_cache)."""
    new_cache = {}
    if cfg.family in ("dense", "vlm", "moe"):
        def body_factory(use_mla):
            def body(p, c, h):
                hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
                if use_mla:
                    o, ck, kr = A.mla_decode(p["attn"], hn, c["c_kv"],
                                             c["k_rope"], pos, cfg)
                    nc = {"c_kv": ck, "k_rope": kr}
                else:
                    o, k, v = A.gqa_decode(p["attn"], hn, c["k"], c["v"],
                                           pos, cfg)
                    nc = {"k": k, "v": v}
                h = h + o
                hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
                if "moe" in p:
                    # Decode: capacity = #tokens so no token is ever dropped
                    # (drops are a throughput knob for training only).
                    y, _ = MOE.moe_apply(p["moe"], hn, cfg,
                                         capacity=hn.shape[0] * hn.shape[1])
                    h = h + y
                else:
                    h = h + L.mlp(p["mlp"], hn)
                return h, nc
            return body
        for name in ("blocks", "blocks_dense", "blocks_moe"):
            if name in params:
                x, nc = _scan_decode(body_factory(cfg.use_mla),
                                     params[name], cache[name], x)
                new_cache[name] = nc
    elif cfg.family == "hybrid":
        def rec_body(p, c, h):
            hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
            o, nh, ncv = R.recurrent_decode(p["rec"], hn, c["h"], c["conv"], cfg)
            h = h + o
            hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
            return h + L.mlp(p["mlp"], hn), {"h": nh, "conv": ncv}

        def super_body(p, c, h):
            h, c1 = rec_body(p["rec1"], c["rec1"], h)
            h, c2 = rec_body(p["rec2"], c["rec2"], h)
            hn = L.rmsnorm(p["attn"]["ln1"], h, cfg.norm_eps)
            o, k, v = A.gqa_decode(p["attn"]["attn"], hn, c["attn"]["k"],
                                   c["attn"]["v"], pos, cfg,
                                   window=cfg.local_window)
            h = h + o
            hn = L.rmsnorm(p["attn"]["ln2"], h, cfg.norm_eps)
            h = h + L.mlp(p["attn"]["mlp"], hn)
            return h, {"rec1": c1, "rec2": c2, "attn": {"k": k, "v": v}}

        x, nc = _scan_decode(super_body, params["super"], cache["super"], x)
        new_cache["super"] = nc
        if "extra" in params:
            x, nc = _scan_decode(rec_body, params["extra"], cache["extra"], x)
            new_cache["extra"] = nc
    elif cfg.family == "ssm":
        def body(p, c, h):
            hn = L.rmsnorm(p["ln"], h, cfg.norm_eps)
            o, ns, ncv = M.mamba2_decode(p["ssm"], hn, c["ssm"], c["conv"], cfg)
            return h + o, {"ssm": ns, "conv": ncv}
        x, nc = _scan_decode(body, params["blocks"], cache["blocks"], x)
        new_cache["blocks"] = nc
    else:
        raise ValueError(cfg.family)
    return x, new_cache
