from repro.optim.adamw import AdamWConfig, init_state, apply_updates, global_norm
from repro.optim.schedule import SCHEDULES, wsd, warmup_cosine, default_schedule_for

__all__ = ["AdamWConfig", "init_state", "apply_updates", "global_norm",
           "SCHEDULES", "wsd", "warmup_cosine", "default_schedule_for"]
