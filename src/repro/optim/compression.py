"""Gradient compression for the slow cross-pod axis.

Two schemes, both with error feedback (the residual of the compression step
is added back before the next step, so compression error doesn't bias the
optimizer -- standard distributed-training practice):

  * int8 stochastic-rounding quantization (8x over f32, 2x over bf16) --
    applied per-tensor with a shared absmax scale;
  * top-k sparsification (magnitude) with dense fallback for small tensors.

The launcher applies compression only to the gradient all-reduce over the
``pod`` axis (the low-bandwidth hop); intra-pod reductions stay full
precision.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def int8_quantize(x: jax.Array, key: jax.Array):
    """Stochastic-rounding int8 quantization.  Returns (q, scale)."""
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = absmax / 127.0
    scaled = x.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree_int8(grads, key):
    """Quantize every leaf; returns (quantized tree, residual tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    qs, residuals = [], []
    for leaf, k in zip(leaves, keys):
        q, s = int8_quantize(leaf, k)
        deq = int8_dequantize(q, s).astype(leaf.dtype)
        qs.append((q, s))
        residuals.append(leaf - deq)
    return jax.tree.unflatten(treedef, qs), \
        jax.tree.unflatten(treedef, residuals)


def decompress_tree_int8(qtree, dtype=jnp.float32):
    return jax.tree.map(lambda qs: int8_dequantize(*qs).astype(dtype),
                        qtree, is_leaf=lambda x: isinstance(x, tuple))


def topk_sparsify(x: jax.Array, frac: float = 0.01):
    """Keep the top-frac magnitudes; returns (values, flat indices, residual)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    dense = jnp.zeros_like(flat).at[idx].set(kept)
    residual = (flat - dense).reshape(x.shape)
    return kept, idx, residual


def topk_densify(vals, idx, shape, dtype=jnp.float32):
    flat = jnp.zeros(int(jnp.prod(jnp.array(shape))), dtype).at[idx].set(vals)
    return flat.reshape(shape)
