"""AdamW with decoupled weight decay, global-norm clipping, and optional
parameter-dtype-separate master copies.  Pure pytree functions (no optax
dependency) so optimizer state shards with the same NamedSharding rules as
params (ZeRO-style: m/v inherit the param PartitionSpec, additionally sharded
over the data axis by dist.fsdp).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
