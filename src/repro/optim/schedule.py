"""Learning-rate schedules: cosine, linear, and WSD (warmup-stable-decay).

WSD (arXiv:2404.06395, MiniCPM) is the default schedule for minicpm-2b: a
linear warmup, a long stable plateau, then a short (10%) exponential-ish
decay -- enabling continual training from any plateau checkpoint.
"""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def wsd(step, *, peak_lr: float, warmup: int, total: int,
        decay_frac: float = 0.1, final_frac: float = 0.01):
    """Warmup -> stable plateau -> decay over the last decay_frac of steps."""
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    decay_start = total * (1 - decay_frac)
    prog = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1),
                    0, 1)
    decay = peak_lr * jnp.exp(jnp.log(final_frac) * prog)
    stable = jnp.full_like(step, peak_lr)
    out = jnp.where(step < warmup, warm,
                    jnp.where(step < decay_start, stable, decay))
    return out


def constant(step, *, peak_lr: float, **_):
    return jnp.full_like(jnp.asarray(step, jnp.float32), peak_lr)


SCHEDULES = {"cosine": warmup_cosine, "wsd": wsd, "constant": constant}


def default_schedule_for(arch_name: str) -> str:
    return "wsd" if "minicpm" in arch_name else "cosine"
