"""Shared Pallas kernel bodies: spatially-tiled tap-loop GEMMs over
phase-split operands.

This is the TPU-native datapath of BP-im2col.  The paper's RTL address
generators turn a virtual zero-spaced lowered matrix into fetches of compact
data; here the same mapping is resolved *statically* into a list of "taps"
``(plane, du, dv)`` over a phase-split compact tensor, and the kernel is a
dense multi-tap GEMM (the tap tables are built per axis by ``ops.py``, so
the list is exactly the REAL taps: asymmetric strides and dilated kernels
change the table, never the kernel bodies below):

    out[b, oh, ow, :COUT] += src[plane, b, oh+du, ow+dv, :CIN] @ w[tap]

Every load is a static (or grid-offset) VMEM slice -- no gathers, no
zero-space bytes ever enter VMEM, and every MAC feeds the MXU with dense
128-aligned tiles.  Three ops share the kernel bodies:

  * forward conv         -> ``tap_gemm``        (src = phase-split padded input)
  * input grad (transposed mode, ALL output phases fused into one launch)
                         -> ``tap_gemm_phased`` (src = padded compact dY)
  * weight grad (dilated mode)
                         -> ``tap_wgrad``       (contraction over batch x space)

Spatial tiling: every builder takes ``oh_tile``/``ow_tile`` and adds
output-row/col block dimensions to the grid.  The source BlockSpec uses
*element-offset* index maps (``pl.Unblocked``) so consecutive spatial tiles
overlap by the tap halo ``(max du, max dv)`` -- the per-tile VMEM slice is
``(tile + halo)`` rows/cols and a tap reads ``src[du : du+tile]`` inside it.
That is what lets shapes whose full spatial plane exceeds VMEM still run on
the Pallas path instead of falling back.

Grid conventions (contraction dims INNERMOST so f32 scratch accumulates):
  tap_gemm        grid = (B, n_th, n_tw, cout_steps, cin_steps)
  tap_gemm_phased grid = (PH, B, n_th, n_tw, cout_steps, cin_steps) with
                  PH = s_h*s_w output stride phases (per-axis, so
                  asymmetric strides just change PH); the leading phase dim
                  selects the per-phase weight block and tap table, nothing
                  else -- one pallas_call per conv.
  tap_wgrad       grid = (cin_steps, cout_steps, B, n_th, n_tw); batch and
                  space are contraction dims, accumulated in an f32 VMEM
                  scratch and flushed to the output block exactly once.

All shapes entering ``pl.pallas_call`` are static; tile sizes are chosen by
``ops.py`` under an explicit VMEM budget.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _pad_hw(x: jax.Array, h_axis: int, rows: int, cols: int) -> jax.Array:
    """Zero-pad two adjacent spatial axes up to (rows, cols)."""
    h, w = x.shape[h_axis], x.shape[h_axis + 1]
    if h >= rows and w >= cols:
        return x
    pads = [(0, 0)] * x.ndim
    pads[h_axis] = (0, max(0, rows - h))
    pads[h_axis + 1] = (0, max(0, cols - w))
    return jnp.pad(x, pads)


def _taps_halo(taps) -> tuple[int, int]:
    if not taps:
        return 0, 0
    return max(t[-2] for t in taps), max(t[-1] for t in taps)


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _tap_gemm_kernel(src_ref, w_ref, out_ref, acc_ref, *,
                     taps: tuple[tuple[int, int, int], ...],
                     th: int, tw: int, cin_steps: int):
    """out tile = sum_t src[p_t, 0, du_t:du_t+th, dv_t:dv_t+tw, :] @ w[t]."""
    cin_step = pl.program_id(4)

    @pl.when(cin_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for t, (p, du, dv) in enumerate(taps):
        xs = src_ref[p, 0, du:du + th, dv:dv + tw, :]
        xs = xs.reshape(th * tw, xs.shape[-1])
        acc_ref[...] += jax.lax.dot(
            xs, w_ref[t], preferred_element_type=jnp.float32)

    @pl.when(cin_step == cin_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].reshape(
            1, th, tw, out_ref.shape[-1]).astype(out_ref.dtype)


def _tap_gemm_phased_kernel(src_ref, w_ref, out_ref, acc_ref, *,
                            phase_taps: tuple[tuple[tuple[int, int, int], ...],
                                              ...],
                            th: int, tw: int, cin_steps: int):
    """Fused input-grad body: the leading grid dim is the output stride
    phase; it selects which tap table runs and which weight block was
    loaded.  Phases with an empty tap table write a zero tile (those rows
    of dI receive no contribution)."""
    phase = pl.program_id(0)
    cin_step = pl.program_id(5)

    @pl.when(cin_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for p, taps in enumerate(phase_taps):
        if not taps:
            continue

        @pl.when(phase == p)
        def _run(taps=taps):
            for (j, du, dv) in taps:
                xs = src_ref[0, du:du + th, dv:dv + tw, :]
                xs = xs.reshape(th * tw, xs.shape[-1])
                acc_ref[...] += jax.lax.dot(
                    xs, w_ref[0, j], preferred_element_type=jnp.float32)

    @pl.when(cin_step == cin_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].reshape(
            1, 1, th, tw, out_ref.shape[-1]).astype(out_ref.dtype)


def _tap_wgrad_kernel(src_ref, dy_ref, out_ref, acc_ref, *,
                      taps: tuple[tuple[int, int, int], ...],
                      th: int, tw: int, contraction_steps: int):
    """acc[t, :, :] += src[p_t, 0, du:du+th, dv:dv+tw, :].T @ dy tile.

    Batch AND spatial tiles are contraction dims; partial sums live in the
    f32 VMEM scratch and the output block is written exactly once, so it is
    never round-tripped through HBM between contraction steps."""
    b = pl.program_id(2)
    r = pl.program_id(3)
    c = pl.program_id(4)
    step = (b * pl.num_programs(3) + r) * pl.num_programs(4) + c

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dyr = dy_ref[0].reshape(th * tw, dy_ref.shape[-1])
    for t, (p, du, dv) in enumerate(taps):
        xs = src_ref[p, 0, du:du + th, dv:dv + tw, :]
        xs = xs.reshape(th * tw, xs.shape[-1])
        # (CIN, th*tw) @ (th*tw, COUT) via dot_general contraction on dim 0.
        acc_ref[t, :, :] += jax.lax.dot_general(
            xs, dyr, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(step == contraction_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def tap_gemm(src: jax.Array, w: jax.Array,
             taps: Sequence[tuple[int, int, int]],
             oh: int, ow: int, *,
             cin_tile: int, cout_tile: int,
             oh_tile: int | None = None, ow_tile: int | None = None,
             out_dtype=None, interpret: bool = True) -> jax.Array:
    """Spatially-tiled multi-tap GEMM.

    src : (P, B, Hs, Ws, CIN)   phase-split compact source
    w   : (T, CIN, COUT)        per-tap weight slices, T == len(taps)
    out : (B, oh, ow, COUT)

    ``oh_tile``/``ow_tile`` block the output spatial plane; each source
    block is the matching window plus the tap halo, fetched via an
    element-offset (Unblocked) index map so consecutive blocks overlap.
    """
    p_, b_, hs, ws, cin = src.shape
    t_, cin2, cout = w.shape
    assert cin == cin2 and t_ == len(taps)
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    th = oh_tile or oh
    tw = ow_tile or ow
    n_th, n_tw = _cdiv(oh, th), _cdiv(ow, tw)
    halo_h, halo_w = _taps_halo(taps)
    src = _pad_hw(src, 2, n_th * th + halo_h, n_tw * tw + halo_w)
    cin_steps = cin // cin_tile
    cout_steps = cout // cout_tile
    out_dtype = out_dtype or src.dtype

    kernel = functools.partial(
        _tap_gemm_kernel, taps=tuple(taps), th=th, tw=tw,
        cin_steps=cin_steps)
    out = pl.pallas_call(
        kernel,
        grid=(b_, n_th, n_tw, cout_steps, cin_steps),
        in_specs=[
            pl.BlockSpec((p_, 1, th + halo_h, tw + halo_w, cin_tile),
                         lambda b, r, c, co, ci:
                         (0, b, r * th, c * tw, ci * cin_tile),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((t_, cin_tile, cout_tile),
                         lambda b, r, c, co, ci: (0, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, th, tw, cout_tile),
                               lambda b, r, c, co, ci: (b, r, c, co)),
        out_shape=jax.ShapeDtypeStruct((b_, n_th * th, n_tw * tw, cout),
                                       out_dtype),
        scratch_shapes=[pltpu.VMEM((th * tw, cout_tile), jnp.float32)],
        interpret=interpret,
    )(src, w)
    return out[:, :oh, :ow, :]


def tap_gemm_phased(src: jax.Array, w: jax.Array,
                    phase_taps: Sequence[Sequence[tuple[int, int, int]]],
                    oh: int, ow: int, *,
                    cin_tile: int, cout_tile: int,
                    oh_tile: int | None = None, ow_tile: int | None = None,
                    out_dtype=None, interpret: bool = True) -> jax.Array:
    """All-phases input-grad tap GEMM in ONE pallas_call.

    src : (B, Hs, Ws, CIN)      globally padded compact dY (shared by every
                                phase -- tap offsets are pre-shifted so all
                                phases read it at a uniform base)
    w   : (PH, T, CIN, COUT)    per-phase stacked tap weights, zero-padded to
                                the widest tap table T
    out : (PH, B, oh, ow, COUT) phase-major planes, un-phase-split by the
                                caller with a pure reshape/transpose

    phase_taps[p] is a tuple of ``(j, du, dv)``: tap j of phase p reads the
    source window at halo offset (du, dv).
    """
    b_, hs, ws, cin = src.shape
    ph_, t_, cin2, cout = w.shape
    assert cin == cin2 and ph_ == len(phase_taps)
    assert all(j < t_ for taps in phase_taps for (j, _, _) in taps)
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    th = oh_tile or oh
    tw = ow_tile or ow
    n_th, n_tw = _cdiv(oh, th), _cdiv(ow, tw)
    halo_h = max((t[1] for taps in phase_taps for t in taps), default=0)
    halo_w = max((t[2] for taps in phase_taps for t in taps), default=0)
    src = _pad_hw(src, 1, n_th * th + halo_h, n_tw * tw + halo_w)
    cin_steps = cin // cin_tile
    cout_steps = cout // cout_tile
    out_dtype = out_dtype or src.dtype

    kernel = functools.partial(
        _tap_gemm_phased_kernel,
        phase_taps=tuple(tuple(taps) for taps in phase_taps),
        th=th, tw=tw, cin_steps=cin_steps)
    out = pl.pallas_call(
        kernel,
        grid=(ph_, b_, n_th, n_tw, cout_steps, cin_steps),
        in_specs=[
            pl.BlockSpec((1, th + halo_h, tw + halo_w, cin_tile),
                         lambda p, b, r, c, co, ci:
                         (b, r * th, c * tw, ci * cin_tile),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((1, t_, cin_tile, cout_tile),
                         lambda p, b, r, c, co, ci: (p, 0, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, 1, th, tw, cout_tile),
                               lambda p, b, r, c, co, ci: (p, b, r, c, co)),
        out_shape=jax.ShapeDtypeStruct(
            (ph_, b_, n_th * th, n_tw * tw, cout), out_dtype),
        scratch_shapes=[pltpu.VMEM((th * tw, cout_tile), jnp.float32)],
        interpret=interpret,
    )(src, w)
    return out[:, :, :oh, :ow, :]


def tap_wgrad(src: jax.Array, dy: jax.Array,
              taps: Sequence[tuple[int, int, int]],
              oh: int, ow: int, *,
              cin_tile: int, cout_tile: int,
              oh_tile: int | None = None, ow_tile: int | None = None,
              interpret: bool = True) -> jax.Array:
    """Weight gradient: out (T, CIN, COUT) summed over batch and space.

    src : (P, B, Hs, Ws, CIN)   phase-split padded input
    dy  : (B, oh, ow, COUT)     compact output loss

    Batch and spatial tiles are contraction grid dims; the partial sums
    accumulate in an f32 VMEM scratch (never through HBM).
    """
    p_, b_, hs, ws, cin = src.shape
    b2, oh2, ow2, cout = dy.shape
    assert b2 == b_ and oh2 == oh and ow2 == ow
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    t_ = len(taps)
    th = oh_tile or oh
    tw = ow_tile or ow
    n_th, n_tw = _cdiv(oh, th), _cdiv(ow, tw)
    halo_h, halo_w = _taps_halo(taps)
    src = _pad_hw(src, 2, n_th * th + halo_h, n_tw * tw + halo_w)
    dy = _pad_hw(dy, 1, n_th * th, n_tw * tw)   # zero rows add nothing

    kernel = functools.partial(
        _tap_wgrad_kernel, taps=tuple(taps), th=th, tw=tw,
        contraction_steps=b_ * n_th * n_tw)
    return pl.pallas_call(
        kernel,
        grid=(cin // cin_tile, cout // cout_tile, b_, n_th, n_tw),
        in_specs=[
            pl.BlockSpec((p_, 1, th + halo_h, tw + halo_w, cin_tile),
                         lambda ci, co, b, r, c:
                         (0, b, r * th, c * tw, ci * cin_tile),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((1, th, tw, cout_tile),
                         lambda ci, co, b, r, c: (b, r, c, co)),
        ],
        out_specs=pl.BlockSpec((t_, cin_tile, cout_tile),
                               lambda ci, co, b, r, c: (0, ci, co)),
        out_shape=jax.ShapeDtypeStruct((t_, cin, cout), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t_, cin_tile, cout_tile), jnp.float32)],
        interpret=interpret,
    )(src, dy)
