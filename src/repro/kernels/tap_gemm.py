"""Shared Pallas kernel bodies: tap-loop GEMMs over phase-split operands.

This is the TPU-native datapath of BP-im2col.  The paper's RTL address
generators turn a virtual zero-spaced lowered matrix into fetches of compact
data; here the same mapping is resolved *statically* into a list of "taps"
``(plane, du, dv)`` over a phase-split compact tensor, and the kernel is a
dense multi-tap GEMM:

    out[b, oh, ow, :COUT] += src[plane, b, oh+du, ow+dv, :CIN] @ w[tap]

Every load is a static (or grid-offset) VMEM slice -- no gathers, no
zero-space bytes ever enter VMEM, and every MAC feeds the MXU with dense
128-aligned tiles.  Three ops share the two kernel bodies:

  * forward conv         -> ``tap_gemm``    (src = phase-split padded input)
  * input grad (transposed mode, per output phase)
                         -> ``tap_gemm``    (src = padded compact dY)
  * weight grad (dilated mode)
                         -> ``tap_wgrad``   (contraction over batch x space)

Grid conventions:
  tap_gemm   grid = (B, cin_steps, cout_steps); cin is the contraction dim,
             accumulated in an f32 VMEM scratch.
  tap_wgrad  grid = (cin_steps, cout_steps, B); batch is the contraction dim,
             accumulated directly into the f32 output block.

All shapes entering ``pl.pallas_call`` are static; tile sizes are chosen by
``ops.py`` under an explicit VMEM budget.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------

def _tap_gemm_kernel(src_ref, w_ref, out_ref, acc_ref, *,
                     taps: tuple[tuple[int, int, int], ...],
                     oh: int, ow: int, cin_steps: int):
    """out[0, :, :, :] = sum_t src[p_t, 0, du_t:du_t+oh, dv_t:dv_t+ow, :] @ w[t].

    Grid (b, cout_steps, cin_steps): the contraction dim (cin) is INNERMOST so
    the f32 scratch accumulates correctly across steps.
    """
    cin_step = pl.program_id(2)

    @pl.when(cin_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for t, (p, du, dv) in enumerate(taps):
        xs = src_ref[p, 0, du:du + oh, dv:dv + ow, :]
        xs = xs.reshape(oh * ow, xs.shape[-1])
        acc_ref[...] += jax.lax.dot(
            xs, w_ref[t], preferred_element_type=jnp.float32)

    @pl.when(cin_step == cin_steps - 1)
    def _flush():
        out_ref[...] = acc_ref[...].reshape(
            1, oh, ow, out_ref.shape[-1]).astype(out_ref.dtype)


def _tap_wgrad_kernel(src_ref, dy_ref, out_ref, *,
                      taps: tuple[tuple[int, int, int], ...],
                      oh: int, ow: int, b_steps: int):
    """out[t, :, :] += src[p_t, 0, du:du+oh, dv:dv+ow, :].T @ dy[0, :, :, :]."""
    b = pl.program_id(2)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dyr = dy_ref[0].reshape(oh * ow, dy_ref.shape[-1])
    for t, (p, du, dv) in enumerate(taps):
        xs = src_ref[p, 0, du:du + oh, dv:dv + ow, :]
        xs = xs.reshape(oh * ow, xs.shape[-1])
        # (CIN, oh*ow) @ (oh*ow, COUT) via dot_general contraction on dim 0.
        out_ref[t, :, :] += jax.lax.dot_general(
            xs, dyr, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(out_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call builders
# ---------------------------------------------------------------------------

def tap_gemm(src: jax.Array, w: jax.Array,
             taps: Sequence[tuple[int, int, int]],
             oh: int, ow: int, *,
             cin_tile: int, cout_tile: int,
             out_dtype=None, interpret: bool = True) -> jax.Array:
    """Multi-tap GEMM.

    src : (P, B, Hs, Ws, CIN)   phase-split compact source
    w   : (T, CIN, COUT)        per-tap weight slices, T == len(taps)
    out : (B, oh, ow, COUT)
    """
    p_, b_, hs, ws, cin = src.shape
    t_, cin2, cout = w.shape
    assert cin == cin2 and t_ == len(taps)
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    cin_steps = cin // cin_tile
    cout_steps = cout // cout_tile
    out_dtype = out_dtype or src.dtype

    kernel = functools.partial(
        _tap_gemm_kernel, taps=tuple(taps), oh=oh, ow=ow, cin_steps=cin_steps)
    return pl.pallas_call(
        kernel,
        grid=(b_, cout_steps, cin_steps),
        in_specs=[
            pl.BlockSpec((p_, 1, hs, ws, cin_tile),
                         lambda b, co, ci: (0, b, 0, 0, ci)),
            pl.BlockSpec((t_, cin_tile, cout_tile),
                         lambda b, co, ci: (0, ci, co)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, cout_tile),
                               lambda b, co, ci: (b, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((b_, oh, ow, cout), out_dtype),
        scratch_shapes=[pltpu.VMEM((oh * ow, cout_tile), jnp.float32)],
        interpret=interpret,
    )(src, w)


def tap_wgrad(src: jax.Array, dy: jax.Array,
              taps: Sequence[tuple[int, int, int]],
              oh: int, ow: int, *,
              cin_tile: int, cout_tile: int,
              interpret: bool = True) -> jax.Array:
    """Weight gradient: out (T, CIN, COUT) summed over batch and space.

    src : (P, B, Hs, Ws, CIN)   phase-split padded input
    dy  : (B, oh, ow, COUT)     compact output loss
    """
    p_, b_, hs, ws, cin = src.shape
    b2, oh2, ow2, cout = dy.shape
    assert b2 == b_ and oh2 == oh and ow2 == ow
    assert cin % cin_tile == 0 and cout % cout_tile == 0
    t_ = len(taps)

    kernel = functools.partial(
        _tap_wgrad_kernel, taps=tuple(taps), oh=oh, ow=ow, b_steps=b_)
    return pl.pallas_call(
        kernel,
        grid=(cin // cin_tile, cout // cout_tile, b_),
        in_specs=[
            pl.BlockSpec((p_, 1, hs, ws, cin_tile),
                         lambda ci, co, b: (0, b, 0, 0, ci)),
            pl.BlockSpec((1, oh, ow, cout_tile),
                         lambda ci, co, b: (b, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((t_, cin_tile, cout_tile),
                               lambda ci, co, b: (0, ci, co)),
        out_shape=jax.ShapeDtypeStruct((t_, cin, cout), jnp.float32),
        interpret=interpret,
    )(src, dy)
