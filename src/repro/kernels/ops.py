"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * layout prep (NCHW -> NHWC, padding, phase-splitting) -- pure reshapes /
    slices on COMPACT data, done once at trace time;
  * static tap-table construction (the BP-im2col address mapping, resolved
    per stride phase);
  * tile-size selection under an explicit VMEM budget, with a documented
    fallback to the pure-jnp phase decomposition when a shape cannot be
    tiled into VMEM (the fallback is semantically identical).

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware set ``repro.kernels.ops.INTERPRET = False``.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.im2col_ref import ConvDims, rot180, zero_pad
from repro.core import phase_decomp
from repro.kernels import tap_gemm as tg

INTERPRET = True
VMEM_BUDGET_BYTES = 14 * 1024 * 1024


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def _to_nhwc(x):
    return x.transpose(0, 2, 3, 1)


def _from_nhwc(x):
    return x.transpose(0, 3, 1, 2)


def _pad_channels(x, mult):
    c = x.shape[-1]
    cp = -(-c // mult) * mult
    if cp == c:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, cp - c)])


def _channel_tile(c: int) -> tuple[int, int]:
    """(padded_c, tile): no padding below 128 channels, 128-tiles above."""
    if c <= 128:
        return c, c
    cp = -(-c // 128) * 128
    return cp, 128


def _phase_split(xp: jax.Array, S: int) -> jax.Array:
    """(B, Hp, Wp, C) -> (S*S, B, ceil(Hp/S), ceil(Wp/S), C) phase planes."""
    b, hp, wp, c = xp.shape
    hp2 = -(-hp // S) * S
    wp2 = -(-wp // S) * S
    xp = jnp.pad(xp, ((0, 0), (0, hp2 - hp), (0, wp2 - wp), (0, 0)))
    xp = xp.reshape(b, hp2 // S, S, wp2 // S, S, c)
    return xp.transpose(2, 4, 0, 1, 3, 5).reshape(S * S, b, hp2 // S, wp2 // S, c)


def _vmem_ok(*arrays_bytes: int) -> bool:
    return sum(arrays_bytes) <= VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# Forward convolution (implicit im2col, phase-split tap GEMM)
# ---------------------------------------------------------------------------

def conv2d_forward(x: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    xn = _to_nhwc(x)                                     # (B, H, W, C)
    xp = zero_pad(xn.transpose(0, 3, 1, 2), d.P_h, d.P_w).transpose(0, 2, 3, 1)
    src = _phase_split(xp, d.S)                          # (S*S, B, HpS, WpS, C)
    cin_p, cin_t = _channel_tile(d.C)
    cout_p, cout_t = _channel_tile(d.N)
    src = _pad_channels(src, cin_p if cin_p == d.C else 128)
    # taps: (phase plane, du, dv) per kernel position
    taps = [((kh % d.S) * d.S + (kw % d.S), kh // d.S, kw // d.S)
            for kh in range(d.K_h) for kw in range(d.K_w)]
    wt = w.transpose(2, 3, 1, 0).reshape(d.K_h * d.K_w, d.C, d.N)
    wt = _pad_channels(wt.transpose(0, 2, 1), cin_p if cin_p == d.C else 128)
    wt = _pad_channels(wt.transpose(0, 2, 1), cout_p if cout_p == d.N else 128)
    bytes_needed = (src.shape[0] * src.shape[2] * src.shape[3] * cin_t * 4
                    + len(taps) * cin_t * cout_t * 4
                    + 2 * d.H_o * d.W_o * cout_t * 4)
    if not _vmem_ok(bytes_needed):
        return jax.lax.conv_general_dilated(
            x, w, (d.S, d.S), [(d.P_h, d.P_h), (d.P_w, d.P_w)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
    y = tg.tap_gemm(src, wt, taps, d.H_o, d.W_o,
                    cin_tile=cin_t, cout_tile=cout_t,
                    out_dtype=x.dtype, interpret=INTERPRET)
    return _from_nhwc(y[..., :d.N])


# ---------------------------------------------------------------------------
# Input gradient (transposed mode): one tap-GEMM per output stride phase
# ---------------------------------------------------------------------------

def conv2d_input_grad(dy: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    a_h, a_w = d.K_h - 1 - d.P_h, d.K_w - 1 - d.P_w
    wf = rot180(w)                                       # (N, C, K_h, K_w)
    dyn = _to_nhwc(dy)                                   # (B, Ho, Wo, N)
    cin_p, cin_t = _channel_tile(d.N)                    # contraction dim = N
    cout_p, cout_t = _channel_tile(d.C)
    di = jnp.zeros((d.B, d.H_i, d.W_i, d.C), dtype=dy.dtype)
    for r_h in range(min(d.S, d.H_i)):
        c_h, m_h, off_h, n_qh = phase_decomp._phase_geometry(
            r_h, a_h, d.S, d.K_h, d.H_i, d.H_o)
        for r_w in range(min(d.S, d.W_i)):
            c_w, m_w, off_w, n_qw = phase_decomp._phase_geometry(
                r_w, a_w, d.S, d.K_w, d.W_i, d.W_o)
            if n_qh == 0 or n_qw == 0 or m_h == 0 or m_w == 0:
                continue
            wk = wf[:, :, c_h::d.S, c_w::d.S][:, :, :m_h, :m_w]
            wk = wk.transpose(2, 3, 0, 1).reshape(m_h * m_w, d.N, d.C)
            wk = _pad_channels(wk.transpose(0, 2, 1),
                               cin_p if cin_p == d.N else 128).transpose(0, 2, 1)
            wk = _pad_channels(wk, cout_p if cout_p == d.C else 128)
            crop_h, crop_w = max(0, off_h), max(0, off_w)
            pad_lo_h, pad_lo_w = max(0, -off_h), max(0, -off_w)
            pad_hi_h = max(0, (n_qh - 1) + off_h + m_h - d.H_o)
            pad_hi_w = max(0, (n_qw - 1) + off_w + m_w - d.W_o)
            src = dyn[:, crop_h:, crop_w:, :]
            src = jnp.pad(src, ((0, 0), (pad_lo_h, pad_hi_h),
                                (pad_lo_w, pad_hi_w), (0, 0)))
            src = _pad_channels(src, cin_p if cin_p == d.N else 128)[None]
            taps = [(0, mh, mw) for mh in range(m_h) for mw in range(m_w)]
            bytes_needed = (src.shape[2] * src.shape[3] * cin_t * 4
                            + len(taps) * cin_t * cout_t * 4
                            + 2 * n_qh * n_qw * cout_t * 4)
            if not _vmem_ok(bytes_needed):
                return phase_decomp.input_grad_phase(dy, w, d)
            out = tg.tap_gemm(src, wk, taps, n_qh, n_qw,
                              cin_tile=cin_t, cout_tile=cout_t,
                              out_dtype=dy.dtype, interpret=INTERPRET)
            di = di.at[:, r_h::d.S, r_w::d.S, :].set(out[..., :d.C])
    return _from_nhwc(di)


# ---------------------------------------------------------------------------
# Weight gradient (dilated mode): strided-view tap GEMM, batch-accumulated
# ---------------------------------------------------------------------------

def conv2d_weight_grad(x: jax.Array, dy: jax.Array, d: ConvDims) -> jax.Array:
    xn = _to_nhwc(x)
    xp = zero_pad(xn.transpose(0, 3, 1, 2), d.P_h, d.P_w).transpose(0, 2, 3, 1)
    src = _phase_split(xp, d.S)
    cin_p, cin_t = _channel_tile(d.C)
    cout_p, cout_t = _channel_tile(d.N)
    src = _pad_channels(src, cin_p if cin_p == d.C else 128)
    dyn = _pad_channels(_to_nhwc(dy), cout_p if cout_p == d.N else 128)
    taps = [((kh % d.S) * d.S + (kw % d.S), kh // d.S, kw // d.S)
            for kh in range(d.K_h) for kw in range(d.K_w)]
    bytes_needed = (src.shape[0] * src.shape[2] * src.shape[3] * cin_t * 4
                    + d.H_o * d.W_o * cout_t * 4
                    + len(taps) * cin_t * cout_t * 4)
    if not _vmem_ok(bytes_needed):
        return phase_decomp.weight_grad_phase(x, dy, d)
    dw = tg.tap_wgrad(src, dyn, taps, d.H_o, d.W_o,
                      cin_tile=cin_t, cout_tile=cout_t, interpret=INTERPRET)
    dw = dw[:, :d.C, :d.N].reshape(d.K_h, d.K_w, d.C, d.N)
    return dw.transpose(3, 2, 0, 1).astype(x.dtype)
