"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * layout prep (NCHW -> NHWC, padding, phase-splitting) -- pure reshapes /
    slices on COMPACT data, done once at trace time;
  * static tap-table construction (the BP-im2col address mapping, resolved
    per stride phase).  Tap tables are built INDEPENDENTLY per axis: the
    phase grid is ``s_h x s_w`` (asymmetric strides included), and a kernel
    dilation (``ConvDims.D_h``/``D_w``) drops the zero taps from the table
    outright -- only the ``k_taps_h * k_taps_w`` real taps are ever
    enumerated, multiplied or planned for, never the ``K_h * K_w``
    zero-dilated extent.  Callers pass the COMPACT (undilated) kernel;
  * tile-plan SEARCH under an explicit VMEM budget: the planners walk
    (spatial tile, cin tile, cout tile) candidates -- full plane first, then
    halving the larger spatial dim, then halving channel tiles -- and take
    the first configuration whose per-grid-step VMEM footprint fits.  A
    shape only falls back to the jnp phase decomposition when even the
    minimal 1x1-spatial / smallest-channel tiling exceeds the budget
    (genuinely degenerate geometry or an absurdly small budget), never
    merely because the full spatial plane is large.

Tap tables and tile choices depend only on the static ``ConvDims`` and the
budget, so they are memoized (``functools.lru_cache``) with the budget as an
explicit cache-key argument.  The budget itself lives on the global config
(``repro.config.vmem_budget_bytes``): ``config.update(...)`` both changes
the default budget every planner resolves AND invalidates these lru caches,
so there is no way to be served a stale plan.  Repeated layer shapes --
every step of a training run retraces the same convs -- skip the search
entirely.  ``tile_plan_cache_info()`` exposes hit counts;
``clear_tile_plan_cache()`` resets; ``plan_events()`` counts planned-vs-
fallback outcomes (one event per unique shape/budget) for benchmarks & CI.

When ``repro.config.autotune`` is not ``"off"``, the public planners
(:func:`forward_plan` / :func:`weight_grad_plan` / :func:`input_grad_plan`)
route through ``repro.kernels.autotune``: the analytic search keeps its
role (first-fit feasibility + fallback/event accounting), but the tile
actually dispatched may be a MEASURED winner -- the top-k analytic
candidates timed on device, persisted in an on-disk plan cache.  The
``"auto"`` engine resolver and every ``conv2d`` dispatch consult tuned
plans exactly as they consult analytic ones, because they all go through
these three entry points.

``repro.config.interpret`` defaults to True because this container is
CPU-only; on real TPU hardware set ``BPIM2COL_INTERPRET=0`` in the
environment (or ``repro.config.update(interpret=False)``) to compile the
kernels with Mosaic instead -- no code edit required.  The pre-config
module globals ``INTERPRET`` / ``VMEM_BUDGET_BYTES`` remain readable and
assignable as deprecated aliases of the config fields.
"""

from __future__ import annotations

import dataclasses
import functools
import sys
import types
import warnings

import jax
import jax.numpy as jnp

from repro.core.config import config
from repro.core.im2col_ref import ConvDims, rot180, zero_insert, zero_pad
from repro.core import phase_decomp
from repro.ft.inject import fault_point
from repro.kernels import tap_gemm as tg
from repro.obs import events as obs_events
from repro.kernels.tap_gemm import _cdiv, _taps_halo

_ELEM_BYTES = 4            # budget in f32 elements (worst case)

#: planned-vs-fallback outcomes, one event per unique (ConvDims, budget)
#: planner invocation (memoized calls do not re-count).
PLAN_EVENTS: dict[str, int] = {}


def _count_event(name: str) -> None:
    PLAN_EVENTS[name] = PLAN_EVENTS.get(name, 0) + 1
    obs_events.emit("plan", name)


def plan_events() -> dict[str, int]:
    return dict(PLAN_EVENTS)


def reset_plan_events() -> None:
    PLAN_EVENTS.clear()
    # Keep the bus-backed view in lockstep with the legacy dict (no-op off).
    obs_events.drop("plan")


def _canonical(d: ConvDims) -> ConvDims:
    """Resolve the P_*_hi = -1 'symmetric' sentinel to explicit high-side
    pads and normalize the S_w stride sentinel so geometrically identical
    layers share one plan-cache entry (and one plan event) no matter how
    the caller spelled the padding/stride."""
    sw = -1 if d.s_w == d.S else d.s_w
    if d.P_h_hi == d.p_h_hi and d.P_w_hi == d.p_w_hi and d.S_w == sw:
        return d
    return dataclasses.replace(d, P_h_hi=d.p_h_hi, P_w_hi=d.p_w_hi, S_w=sw)


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def _to_nhwc(x):
    return x.transpose(0, 2, 3, 1)


def _from_nhwc(x):
    return x.transpose(0, 3, 1, 2)


def _pad_to(x, n: int, axis: int = -1):
    """Zero-pad one axis of ``x`` up to exactly ``n`` (no-op when already
    there).  Every engine uses this to bring channel dims to the plan's
    padded sizes before entering a kernel."""
    c = x.shape[axis]
    if c == n:
        return x
    assert c < n, f"cannot pad axis {axis} from {c} down to {n}"
    pads = [(0, 0)] * x.ndim
    pads[axis % x.ndim] = (0, n - c)
    return jnp.pad(x, pads)


def _channel_tile(c: int) -> tuple[int, int]:
    """(padded_c, tile): no padding below 128 channels, 128-tiles above."""
    if c <= 128:
        return c, c
    cp = -(-c // 128) * 128
    return cp, 128


def _phase_split(xp: jax.Array, s: tuple[int, int]) -> jax.Array:
    """(B, Hp, Wp, C) -> (s_h*s_w, B, ceil(Hp/s_h), ceil(Wp/s_w), C) phase
    planes; plane index = (h % s_h) * s_w + (w % s_w)."""
    s_h, s_w = s
    b, hp, wp, c = xp.shape
    hp2 = -(-hp // s_h) * s_h
    wp2 = -(-wp // s_w) * s_w
    xp = jnp.pad(xp, ((0, 0), (0, hp2 - hp), (0, wp2 - wp), (0, 0)))
    xp = xp.reshape(b, hp2 // s_h, s_h, wp2 // s_w, s_w, c)
    return xp.transpose(2, 4, 0, 1, 3, 5).reshape(
        s_h * s_w, b, hp2 // s_h, wp2 // s_w, c)


def _phase_unsplit(planes: jax.Array, s: tuple[int, int],
                   h: int, w: int) -> jax.Array:
    """(s_h*s_w, B, Hq, Wq, C) -> (B, h, w, C): the exact inverse of
    ``_phase_split`` -- a pure reshape/transpose/crop, no scatter."""
    s_h, s_w = s
    s2, b, hq, wq, c = planes.shape
    assert s2 == s_h * s_w
    x = planes.reshape(s_h, s_w, b, hq, wq, c).transpose(2, 3, 0, 4, 1, 5)
    return x.reshape(b, hq * s_h, wq * s_w, c)[:, :h, :w, :]


# ---------------------------------------------------------------------------
# Tile search: (spatial tile, cin tile, cout tile) under the VMEM budget
# ---------------------------------------------------------------------------

def _spatial_candidates(oh: int, ow: int):
    """Full plane first, then halve the larger spatial dim (1x, 2x, 4x, ...
    splits) down to a 1x1 tile."""
    th, tw = oh, ow
    while True:
        yield th, tw
        if th <= 1 and tw <= 1:
            return
        if th >= tw and th > 1:
            th = _cdiv(th, 2)
        else:
            tw = _cdiv(tw, 2)


def _channel_candidates(cin_pad: int, cout_pad: int):
    """Full (<=128) channel tiles first, then halve both while the halves
    still divide the padded channel counts."""
    ci, co = min(cin_pad, 128), min(cout_pad, 128)
    yield ci, co
    while ci > 1 or co > 1:
        nci = ci // 2 if (ci > 1 and ci % 2 == 0
                          and cin_pad % (ci // 2) == 0) else ci
        nco = co // 2 if (co > 1 and co % 2 == 0
                          and cout_pad % (co // 2) == 0) else co
        if (nci, nco) == (ci, co):
            return
        ci, co = nci, nco
        yield ci, co


def _search_tiles(oh, ow, cin_pad, cout_pad, cost_fn, budget):
    """First candidate whose cost fits: spatial splits are exhausted before
    channel tiles shrink, so large planes tile spatially at full MXU width.
    Returns (th, tw, n_th, n_tw, cin_t, cout_t, bytes, fits)."""
    last = None
    for cin_t, cout_t in _channel_candidates(cin_pad, cout_pad):
        for th, tw in _spatial_candidates(oh, ow):
            bytes_needed = cost_fn(th, tw, cin_t, cout_t)
            last = (th, tw, _cdiv(oh, th), _cdiv(ow, tw), cin_t, cout_t,
                    bytes_needed)
            if bytes_needed <= budget:
                return (*last, True)
    return (*last, False)


def _search_tiles_topk(oh, ow, cin_pad, cout_pad, cost_fn, budget, k):
    """Up to ``k`` distinct FITTING candidates in analytic search order (the
    first element is exactly what :func:`_search_tiles` returns when it
    fits): the autotuner's shortlist.  The analytic order ranks by bytes
    moved, so the shortlist is "the analytically best plan plus the next
    finer tilings" -- the region where the analytic model most often
    mispredicts real hardware."""
    out, seen = [], set()
    for cin_t, cout_t in _channel_candidates(cin_pad, cout_pad):
        for th, tw in _spatial_candidates(oh, ow):
            cand = (th, tw, cin_t, cout_t)
            if cand in seen:
                continue
            seen.add(cand)
            bytes_needed = cost_fn(th, tw, cin_t, cout_t)
            if bytes_needed <= budget:
                out.append((th, tw, _cdiv(oh, th), _cdiv(ow, tw),
                            cin_t, cout_t, bytes_needed))
                if len(out) >= k:
                    return out
    return out


# ---------------------------------------------------------------------------
# Memoized tile plans (static per ConvDims x budget)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One Pallas dispatch: channel + spatial tiling, tap table, footprint.

    The trailing autotune fields are metadata only (they do not change the
    dispatch): ``autotuned`` marks a MEASURED winner from
    ``repro.kernels.autotune`` rather than the analytic first-fit,
    ``measured_us`` its best-of-reps wall-clock, ``candidates_timed`` how
    many analytic candidates were raced, and ``cache`` whether the winner
    came from the persistent plan cache (``"hit"``), was tuned fresh
    (``"miss"``) or replaced an invalid persisted entry (``"stale"``).
    Analytic plans leave them at their defaults.
    """
    fits: bool
    cin_pad: int
    cin_tile: int
    cout_pad: int
    cout_tile: int
    taps: tuple[tuple[int, int, int], ...]
    oh_tile: int
    ow_tile: int
    n_th: int
    n_tw: int
    halo_h: int
    halo_w: int
    bytes_needed: int
    autotuned: bool = False
    measured_us: float = -1.0
    candidates_timed: int = 0
    cache: str = ""

    @property
    def spatial_splits(self) -> int:
        return self.n_th * self.n_tw

    @property
    def tile_key(self) -> tuple[int, int, int, int]:
        """The persisted identity of one candidate: what the autotuner
        stores and what :func:`plan_from_tile` revalidates."""
        return (self.oh_tile, self.ow_tile, self.cin_tile, self.cout_tile)


@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """Fused input-grad dispatch: uniform geometry for ALL S*S output stride
    phases, realized as ONE ``tap_gemm_phased`` launch.

    Per-phase tap offsets are pre-shifted by ``off_phase - min(off)`` so
    every phase reads the same globally padded dY at a uniform base; the
    output planes are un-phase-split by the inverse of ``_phase_split``.
    """
    n_qh: int            # uniform per-phase output rows = ceil(H_i / s_h)
    n_qw: int
    g_lo_h: int          # global low-side dY padding (covers min offset)
    g_lo_w: int
    t_max: int           # widest per-phase tap table (stack padded to this)
    phase_specs: tuple   # per plane r_h*s_w+r_w: (row idxs, col idxs) into
                         # rot180(compact kernel), or None (phase gets zero)
    phase_taps: tuple    # per plane: tuple[(j, du, dv), ...]
    tile: TilePlan


def _forward_taps(d: ConvDims) -> tuple[tuple[int, int, int], ...]:
    """Real kernel tap (kh, kw) -> (phase plane, du, dv) over the split
    input.  Per-axis phases (``s_h x s_w`` planes) and dilation-native:
    only effective positions that hold a real tap (multiples of D_h/D_w)
    are enumerated, so a dilated kernel contributes ``k_taps_h * k_taps_w``
    GEMMs instead of ``K_h * K_w`` -- the zero taps are skipped at plan
    time, not multiplied at run time."""
    return tuple(((kh % d.s_h) * d.s_w + (kw % d.s_w),
                  kh // d.s_h, kw // d.s_w)
                 for kh in range(0, d.K_h, d.D_h)
                 for kw in range(0, d.K_w, d.D_w))


def _budget_or_default(budget: int | None) -> int:
    return config.vmem_budget_bytes if budget is None else budget


def _forward_geom(d: ConvDims):
    """(cin_pad, cout_pad, taps, halo_h, halo_w, cost_fn) of a forward."""
    cin_p, _ = _channel_tile(d.C)
    cout_p, _ = _channel_tile(d.N)
    taps = _forward_taps(d)
    halo_h, halo_w = _taps_halo(taps)
    s2 = d.s_h * d.s_w

    def cost(th, tw, cit, cot):
        return _ELEM_BYTES * (s2 * (th + halo_h) * (tw + halo_w) * cit
                              + len(taps) * cit * cot
                              + 2 * th * tw * cot)

    return cin_p, cout_p, taps, halo_h, halo_w, cost


def _weight_grad_geom(d: ConvDims):
    cin_p, _ = _channel_tile(d.C)
    cout_p, _ = _channel_tile(d.N)
    taps = _forward_taps(d)
    halo_h, halo_w = _taps_halo(taps)
    s2 = d.s_h * d.s_w

    def cost(th, tw, cit, cot):
        return _ELEM_BYTES * (s2 * (th + halo_h) * (tw + halo_w) * cit
                              + th * tw * cot
                              + 2 * len(taps) * cit * cot)

    return cin_p, cout_p, taps, halo_h, halo_w, cost


@functools.lru_cache(maxsize=4096)
def _input_grad_geom(d: ConvDims):
    """The fused-phase geometry shared by every input-grad tile candidate:
    (cin_pad, cout_pad, n_qh, n_qw, g_lo_h, g_lo_w, t_max, specs, taps_all,
    halo_h, halo_w).

    Row and column tap tables are independent: each axis runs its own
    ``phase_geometry`` under its own stride, and a kernel dilation drops
    the phase taps that land on a zero row/col of the dilated kernel
    (effective tap ``c + m*s`` is real iff it is a multiple of ``D``)."""
    s_h, s_w = d.s_h, d.s_w
    a_h, a_w = d.K_h - 1 - d.P_h, d.K_w - 1 - d.P_w
    cin_p, _ = _channel_tile(d.N)      # contraction dim = N
    cout_p, _ = _channel_tile(d.C)
    n_qh, n_qw = _cdiv(d.H_i, s_h), _cdiv(d.W_i, s_w)
    geo_h = [phase_decomp.phase_geometry(r, a_h, s_h, d.K_h, d.H_i, d.H_o)
             for r in range(s_h)]
    geo_w = [phase_decomp.phase_geometry(r, a_w, s_w, d.K_w, d.W_i, d.W_o)
             for r in range(s_w)]
    # Per-axis (source offset m, compact-kernel index) lists, zero taps
    # dropped: rot180 commutes with dilation, so effective position
    # c + m*s of the rotated kernel is real iff divisible by D, and its
    # compact index is (c + m*s) // D.
    taps_h = [tuple((m, (geo_h[r][0] + m * s_h) // d.D_h)
                    for m in range(geo_h[r][1])
                    if (geo_h[r][0] + m * s_h) % d.D_h == 0)
              for r in range(s_h)]
    taps_w = [tuple((m, (geo_w[r][0] + m * s_w) // d.D_w)
                    for m in range(geo_w[r][1])
                    if (geo_w[r][0] + m * s_w) % d.D_w == 0)
              for r in range(s_w)]
    active = {(r_h, r_w) for r_h in range(s_h) for r_w in range(s_w)
              if r_h < d.H_i and r_w < d.W_i
              and taps_h[r_h] and taps_w[r_w]}
    if active:
        min_off_h = min(geo_h[r][2] for r, _ in active)
        min_off_w = min(geo_w[c][2] for _, c in active)
    else:                                  # dI identically zero; still plan
        min_off_h = min_off_w = 0
    base_h, g_lo_h = max(0, min_off_h), max(0, -min_off_h)
    base_w, g_lo_w = max(0, min_off_w), max(0, -min_off_w)

    specs, taps_all, t_max = [], [], 1
    halo_h = halo_w = 0
    for r_h in range(s_h):
        _, _, off_h, _ = geo_h[r_h]
        for r_w in range(s_w):
            _, _, off_w, _ = geo_w[r_w]
            if (r_h, r_w) not in active:
                specs.append(None)
                taps_all.append(())
                continue
            sh = base_h + (off_h - min_off_h)
            sw = base_w + (off_w - min_off_w)
            th_, tw_ = taps_h[r_h], taps_w[r_w]
            taps_all.append(tuple(
                (ih * len(tw_) + iw, sh + mh, sw + mw)
                for ih, (mh, _) in enumerate(th_)
                for iw, (mw, _) in enumerate(tw_)))
            specs.append((tuple(kh for _, kh in th_),
                          tuple(kw for _, kw in tw_)))
            t_max = max(t_max, len(th_) * len(tw_))
            halo_h = max(halo_h, sh + th_[-1][0])
            halo_w = max(halo_w, sw + tw_[-1][0])
    return (cin_p, cout_p, n_qh, n_qw, g_lo_h, g_lo_w, t_max,
            tuple(specs), tuple(taps_all), halo_h, halo_w)


def _input_grad_cost(t_max: int, halo_h: int, halo_w: int):
    def cost(th, tw, cit, cot):
        return _ELEM_BYTES * ((th + halo_h) * (tw + halo_w) * cit
                              + t_max * cit * cot
                              + 2 * th * tw * cot)
    return cost


def _phase_plan_of(d: ConvDims, geom, tile: TilePlan) -> PhasePlan:
    _, _, n_qh, n_qw, g_lo_h, g_lo_w, t_max, specs, taps_all, _, _ = geom
    return PhasePlan(n_qh, n_qw, g_lo_h, g_lo_w, t_max, specs, taps_all,
                     tile)


def _autotuned(role: str, d: ConvDims, budget: int, analytic):
    """Route one planner resolution through the measured autotuner when
    ``config.autotune`` enables it.  The analytic result keeps ownership of
    feasibility (fits=False / None never gets tuned -- there is nothing to
    race) and of the planned-vs-fallback event accounting."""
    if config.autotune == "off":
        return analytic
    if analytic is None or not getattr(analytic, "fits", True):
        return analytic
    from repro.kernels import autotune
    return autotune.tuned_plan(role, d, budget, analytic)


def forward_plan(d: ConvDims, budget: int | None = None) -> TilePlan:
    d, budget = _canonical(d), _budget_or_default(budget)
    return _autotuned("forward", d, budget, _forward_plan(d, budget))


@functools.lru_cache(maxsize=4096)
def _forward_plan(d: ConvDims, budget: int) -> TilePlan:
    cin_p, cout_p, taps, halo_h, halo_w, cost = _forward_geom(d)
    th, tw, n_th, n_tw, cit, cot, bytes_needed, fits = _search_tiles(
        d.H_o, d.W_o, cin_p, cout_p, cost, budget)
    _count_event("forward_pallas" if fits else "forward_fallback")
    return TilePlan(fits, cin_p, cit, cout_p, cot, taps, th, tw, n_th, n_tw,
                    halo_h, halo_w, bytes_needed)


def weight_grad_plan(d: ConvDims, budget: int | None = None) -> TilePlan:
    d, budget = _canonical(d), _budget_or_default(budget)
    return _autotuned("weight_grad", d, budget, _weight_grad_plan(d, budget))


@functools.lru_cache(maxsize=4096)
def _weight_grad_plan(d: ConvDims, budget: int) -> TilePlan:
    cin_p, cout_p, taps, halo_h, halo_w, cost = _weight_grad_geom(d)
    th, tw, n_th, n_tw, cit, cot, bytes_needed, fits = _search_tiles(
        d.H_o, d.W_o, cin_p, cout_p, cost, budget)
    _count_event("weight_grad_pallas" if fits else "weight_grad_fallback")
    return TilePlan(fits, cin_p, cit, cout_p, cot, taps, th, tw, n_th, n_tw,
                    halo_h, halo_w, bytes_needed)


def input_grad_plan(d: ConvDims,
                    budget: int | None = None) -> PhasePlan | None:
    d, budget = _canonical(d), _budget_or_default(budget)
    return _autotuned("input_grad", d, budget, _input_grad_plan(d, budget))


@functools.lru_cache(maxsize=4096)
def _input_grad_plan(d: ConvDims, budget: int) -> PhasePlan | None:
    """Single fused dispatch plan for all s_h*s_w output stride phases, or
    None only when even the minimal tiling exceeds the budget (the op then
    falls back to the jnp phase decomposition)."""
    geom = _input_grad_geom(d)
    cin_p, cout_p, n_qh, n_qw, _, _, t_max, _, _, halo_h, halo_w = geom
    th, tw, n_th, n_tw, cit, cot, bytes_needed, fits = _search_tiles(
        n_qh, n_qw, cin_p, cout_p,
        _input_grad_cost(t_max, halo_h, halo_w), budget)
    _count_event("input_grad_pallas" if fits else "input_grad_fallback")
    if not fits:
        return None
    tile = TilePlan(True, cin_p, cit, cout_p, cot, (), th, tw, n_th, n_tw,
                    halo_h, halo_w, bytes_needed)
    return _phase_plan_of(d, geom, tile)


#: the three tap-GEMM pass roles the planners (and the autotuner) speak.
PLAN_ROLES = ("forward", "weight_grad", "input_grad")


# ---------------------------------------------------------------------------
# Halo export for mesh-parallel spatial sharding (repro.dist.conv_parallel)
# ---------------------------------------------------------------------------

def tap_span(d: ConvDims) -> tuple[int, int]:
    """Per-axis extent of the KEPT (real) kernel taps.

    Recovered from the same tap table the tile planners dispatch with
    (:func:`_forward_taps`): a tap ``(plane, du, dv)`` sits at effective
    kernel position ``(du*s_h + plane//s_w, dv*s_w + plane%s_w)``.  Zero
    taps dropped at plan time (dilation) never enter the table, so the
    span is the real footprint -- the quantity a spatial halo exchange
    must cover, with no zero-space counted."""
    taps = _forward_taps(_canonical(d))
    span_h = 1 + max(du * d.s_h + p // d.s_w for p, du, dv in taps)
    span_w = 1 + max(dv * d.s_w + p % d.s_w for p, du, dv in taps)
    return span_h, span_w


def shard_halo(d: ConvDims) -> tuple[tuple[int, int], tuple[int, int]]:
    """Per-axis ``((lo_h, hi_h), (lo_w, hi_w))`` halo rows/cols a spatial
    shard must exchange with its neighbors, in INPUT-plane units.

    Adjacent stride windows overlap by exactly ``span - stride`` rows
    (window ``o`` ends at ``o*s - P + span - 1``; window ``o+1`` starts at
    ``(o+1)*s - P``), so that is the total exchanged per boundary -- the
    tap-table counterpart of the planners' per-tile ``halo_h``/``halo_w``
    (:func:`_taps_halo` measures the same kept taps in phase-split rows).
    The split puts the low padding on the low side: an edge shard's
    ``ppermute`` then receives exactly the zero rows the global padding
    would have provided, and no zero-space ever crosses the wire.  A
    negative ``hi`` means adjacent windows do not even touch the last
    ``-hi`` local rows (e.g. 1x1 stride-2): the shard crops instead of
    exchanging."""
    d = _canonical(d)
    span_h, span_w = tap_span(d)
    return ((d.P_h, span_h - d.s_h - d.P_h),
            (d.P_w, span_w - d.s_w - d.P_w))


def plan_candidates(role: str, d: ConvDims, budget: int | None = None,
                    k: int | None = None):
    """The autotuner's shortlist: up to ``k`` analytically FITTING plans in
    search order (first element == the analytic winner).  ``input_grad``
    candidates are full :class:`PhasePlan` objects sharing one geometry.
    Pure and unmemoized; records no plan events."""
    d, budget = _canonical(d), _budget_or_default(budget)
    k = config.autotune_top_k if k is None else k
    if role == "forward":
        cin_p, cout_p, taps, halo_h, halo_w, cost = _forward_geom(d)
        oh, ow = d.H_o, d.W_o
    elif role == "weight_grad":
        cin_p, cout_p, taps, halo_h, halo_w, cost = _weight_grad_geom(d)
        oh, ow = d.H_o, d.W_o
    elif role == "input_grad":
        geom = _input_grad_geom(d)
        cin_p, cout_p, oh, ow, _, _, t_max, _, _, halo_h, halo_w = geom
        taps, cost = (), _input_grad_cost(t_max, halo_h, halo_w)
    else:
        raise ValueError(f"unknown plan role {role!r}; roles: {PLAN_ROLES}")
    tiles = _search_tiles_topk(oh, ow, cin_p, cout_p, cost, budget, k)
    plans = [TilePlan(True, cin_p, cit, cout_p, cot, taps, th, tw,
                      n_th, n_tw, halo_h, halo_w, bytes_needed)
             for th, tw, n_th, n_tw, cit, cot, bytes_needed in tiles]
    if role == "input_grad":
        return [_phase_plan_of(d, geom, t) for t in plans]
    return plans


def plan_from_tile(role: str, d: ConvDims, budget: int | None,
                   tile) -> TilePlan | PhasePlan | None:
    """Rebuild a dispatchable plan from a PERSISTED candidate identity
    ``(oh_tile, ow_tile, cin_tile, cout_tile)``, revalidating it against
    the current geometry and budget.  Returns None when the tile is no
    longer valid (plan-cache entry gone stale: code changed the geometry,
    the budget shrank, or the entry is garbage) -- the caller re-tunes."""
    d, budget = _canonical(d), _budget_or_default(budget)
    try:
        th, tw, cit, cot = (int(v) for v in tile)
    except (TypeError, ValueError):
        return None
    if role == "forward":
        cin_p, cout_p, taps, halo_h, halo_w, cost = _forward_geom(d)
        oh, ow = d.H_o, d.W_o
    elif role == "weight_grad":
        cin_p, cout_p, taps, halo_h, halo_w, cost = _weight_grad_geom(d)
        oh, ow = d.H_o, d.W_o
    elif role == "input_grad":
        geom = _input_grad_geom(d)
        cin_p, cout_p, oh, ow, _, _, t_max, _, _, halo_h, halo_w = geom
        taps, cost = (), _input_grad_cost(t_max, halo_h, halo_w)
    else:
        raise ValueError(f"unknown plan role {role!r}; roles: {PLAN_ROLES}")
    if not (1 <= th <= oh and 1 <= tw <= ow):
        return None
    if not (1 <= cit <= cin_p and 1 <= cot <= cout_p
            and cin_p % cit == 0 and cout_p % cot == 0):
        return None
    bytes_needed = cost(th, tw, cit, cot)
    if bytes_needed > budget:
        return None
    plan = TilePlan(True, cin_p, cit, cout_p, cot, taps, th, tw,
                    _cdiv(oh, th), _cdiv(ow, tw), halo_h, halo_w,
                    bytes_needed)
    if role == "input_grad":
        return _phase_plan_of(d, geom, plan)
    return plan


_PLANNERS = {"forward_plan": _forward_plan,
             "weight_grad_plan": _weight_grad_plan,
             "input_grad_plan": _input_grad_plan}


def tile_plan_cache_info() -> dict[str, object]:
    """lru_cache stats per planner (hits prove trace-time memoization)."""
    return {name: fn.cache_info() for name, fn in _PLANNERS.items()}


def clear_tile_plan_cache() -> None:
    for fn in _PLANNERS.values():
        fn.cache_clear()


def plan_report(d: ConvDims, budget: int | None = None) -> dict[str, object]:
    """Static per-shape dispatch summary (used by benchmarks and tests).

    ``kernel_taps`` records the zero-skipping: ``real`` is the number of
    taps the Pallas GEMMs actually run (``k_taps_h * k_taps_w``);
    ``materialized`` is what the kernel-materialization lowering would run
    (``K_h * K_w``, the zero-dilated extent).  They differ exactly when the
    layer is dilated."""
    def _tile(p: TilePlan) -> dict[str, object]:
        t = {"fits": p.fits, "spatial_splits": p.spatial_splits,
             "spatial_tile": [p.oh_tile, p.ow_tile],
             "chan_tile": [p.cin_tile, p.cout_tile],
             "halo": [p.halo_h, p.halo_w],
             "taps": len(p.taps),
             "bytes_needed": p.bytes_needed}
        if p.cache:        # the plan went through the autotuner
            t["autotune"] = {"autotuned": p.autotuned,
                             "measured_us": p.measured_us,
                             "candidates_timed": p.candidates_timed,
                             "cache": p.cache}
        return t
    f = forward_plan(d, budget)
    wg = weight_grad_plan(d, budget)
    ig = input_grad_plan(d, budget)
    report = {
        "phases": d.s_h * d.s_w,
        "kernel_taps": {"real": d.k_taps_h * d.k_taps_w,
                        "materialized": d.K_h * d.K_w},
        "forward": _tile(f),
        "weight_grad": _tile(wg),
        "input_grad": ({"fused": True, "t_max": ig.t_max,
                        "taps_total": sum(len(t) for t in ig.phase_taps),
                        **_tile(ig.tile)}
                       if ig is not None else {"fused": False, "fits": False}),
        "pallas_path": bool(f.fits and wg.fits and ig is not None),
    }
    return report


# ---------------------------------------------------------------------------
# Forward convolution (implicit im2col, phase-split tap GEMM)
# ---------------------------------------------------------------------------

def conv2d_forward(x: jax.Array, w: jax.Array, d: ConvDims,
                   plan: TilePlan | None = None) -> jax.Array:
    """Forward conv through the tap-GEMM kernel.  ``w`` is the COMPACT
    kernel (``k_taps_h x k_taps_w`` spatial extent); when ``d`` carries a
    dilation the tap table skips the zero positions instead of the kernel
    being materialized to ``K_h x K_w``.  ``plan`` overrides the planner
    (the autotuner races explicit candidate plans through here)."""
    assert w.shape[-2:] == (d.k_taps_h, d.k_taps_w), (w.shape, d)
    if plan is None:
        plan = forward_plan(d)
    if not plan.fits:
        return jax.lax.conv_general_dilated(
            x, w, (d.s_h, d.s_w), [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
            rhs_dilation=(d.D_h, d.D_w),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
    fault_point("pallas.forward.launch")
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)
    src = _phase_split(_to_nhwc(xp), (d.s_h, d.s_w))  # (sh*sw, B, Hq, Wq, C)
    src = _pad_to(src, plan.cin_pad)
    wt = w.transpose(2, 3, 1, 0).reshape(d.k_taps_h * d.k_taps_w, d.C, d.N)
    wt = _pad_to(wt, plan.cin_pad, axis=1)
    wt = _pad_to(wt, plan.cout_pad, axis=2)
    y = tg.tap_gemm(src, wt, plan.taps, d.H_o, d.W_o,
                    cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
                    oh_tile=plan.oh_tile, ow_tile=plan.ow_tile,
                    out_dtype=x.dtype, interpret=config.interpret)
    return _from_nhwc(y[..., :d.N])


# ---------------------------------------------------------------------------
# Input gradient (transposed mode): ALL stride phases in one fused launch
# ---------------------------------------------------------------------------

def conv2d_input_grad(dy: jax.Array, w: jax.Array, d: ConvDims,
                      plan: PhasePlan | None = None) -> jax.Array:
    """Input grad through ONE fused tap-GEMM launch.  ``w`` is the COMPACT
    kernel; the per-phase tap tables index straight into ``rot180(w)``
    (dilation's zero taps were dropped at plan time).  ``plan`` overrides
    the planner (autotune candidate racing)."""
    assert w.shape[-2:] == (d.k_taps_h, d.k_taps_w), (w.shape, d)
    pp = input_grad_plan(d) if plan is None else plan
    if pp is None:
        w_eff = zero_insert(w, (d.D_h, d.D_w)) if d.has_dilation else w
        return phase_decomp.input_grad_phase(dy, w_eff, d)
    fault_point("pallas.input_grad.launch")
    tile = pp.tile
    wf = rot180(w)                                 # (N, C, k_taps, k_taps)
    blocks = []
    for spec in pp.phase_specs:
        if spec is None:                                 # phase gets no taps
            blocks.append(jnp.zeros((pp.t_max, d.N, d.C), wf.dtype))
            continue
        rows, cols = spec
        wk = jnp.take(jnp.take(wf, jnp.asarray(rows, jnp.int32), axis=2),
                      jnp.asarray(cols, jnp.int32), axis=3)
        wk = wk.transpose(2, 3, 0, 1).reshape(len(rows) * len(cols),
                                              d.N, d.C)
        blocks.append(_pad_to(wk, pp.t_max, axis=0))
    wk_stack = jnp.stack(blocks)                         # (sh*sw, T, N, C)
    wk_stack = _pad_to(wk_stack, tile.cin_pad, axis=2)
    wk_stack = _pad_to(wk_stack, tile.cout_pad, axis=3)
    src = jnp.pad(_to_nhwc(dy),                          # (B, Ho+lo, Wo+lo, N)
                  ((0, 0), (pp.g_lo_h, 0), (pp.g_lo_w, 0), (0, 0)))
    src = _pad_to(src, tile.cin_pad)
    out = tg.tap_gemm_phased(
        src, wk_stack, pp.phase_taps, pp.n_qh, pp.n_qw,
        cin_tile=tile.cin_tile, cout_tile=tile.cout_tile,
        oh_tile=tile.oh_tile, ow_tile=tile.ow_tile,
        out_dtype=dy.dtype,
        interpret=config.interpret)                   # (sh*sw, B, qh, qw, C)
    di = _phase_unsplit(out[..., :d.C], (d.s_h, d.s_w), d.H_i, d.W_i)
    return _from_nhwc(di)


# ---------------------------------------------------------------------------
# Weight gradient (dilated mode): strided-view tap GEMM, batch-accumulated
# ---------------------------------------------------------------------------

def conv2d_weight_grad(x: jax.Array, dy: jax.Array, d: ConvDims,
                       plan: TilePlan | None = None) -> jax.Array:
    """Weight grad through the tap-wgrad kernel: one accumulated GEMM per
    REAL kernel tap, returned at the compact ``k_taps_h x k_taps_w``
    extent (a dilated kernel's zero taps get no gradient computed at
    all -- they would be discarded anyway).  ``plan`` overrides the
    planner (autotune candidate racing)."""
    if plan is None:
        plan = weight_grad_plan(d)
    if not plan.fits:
        dw = phase_decomp.weight_grad_phase(x, dy, d)   # effective extent
        return dw[..., ::d.D_h, ::d.D_w] if d.has_dilation else dw
    fault_point("pallas.weight_grad.launch")
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)
    src = _phase_split(_to_nhwc(xp), (d.s_h, d.s_w))
    src = _pad_to(src, plan.cin_pad)
    dyn = _pad_to(_to_nhwc(dy), plan.cout_pad)
    dw = tg.tap_wgrad(src, dyn, plan.taps, d.H_o, d.W_o,
                      cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
                      oh_tile=plan.oh_tile, ow_tile=plan.ow_tile,
                      interpret=config.interpret)
    dw = dw[:, :d.C, :d.N].reshape(d.k_taps_h, d.k_taps_w, d.C, d.N)
    return dw.transpose(3, 2, 0, 1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Deprecated module-global aliases (INTERPRET / VMEM_BUDGET_BYTES)
# ---------------------------------------------------------------------------
# The knobs moved to ``repro.config``.  Reads keep working silently (too
# many innocuous introspection sites); ASSIGNMENT -- the old footgun of
# mutating a module global -- forwards to ``config.update`` (which does the
# plan-cache invalidation the global never did) and warns.

_LEGACY_GLOBALS = {"INTERPRET": "interpret",
                   "VMEM_BUDGET_BYTES": "vmem_budget_bytes"}


class _OpsModule(types.ModuleType):
    def __getattr__(self, name):
        field = _LEGACY_GLOBALS.get(name)
        if field is None:
            raise AttributeError(
                f"module {self.__name__!r} has no attribute {name!r}")
        return getattr(config, field)

    def __setattr__(self, name, value):
        field = _LEGACY_GLOBALS.get(name)
        if field is None:
            super().__setattr__(name, value)
            return
        warnings.warn(
            f"setting repro.kernels.ops.{name} is deprecated; use "
            f"repro.config.update({field}=...)",
            DeprecationWarning, stacklevel=2)
        config.update(**{field: value})


sys.modules[__name__].__class__ = _OpsModule
