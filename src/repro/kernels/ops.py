"""Public jit'd wrappers around the Pallas kernels.

Responsibilities:
  * layout prep (NCHW -> NHWC, padding, phase-splitting) -- pure reshapes /
    slices on COMPACT data, done once at trace time;
  * static tap-table construction (the BP-im2col address mapping, resolved
    per stride phase);
  * tile-size selection under an explicit VMEM budget, with a documented
    fallback to the pure-jnp phase decomposition when a shape cannot be
    tiled into VMEM (the fallback is semantically identical).

Tap tables and tile choices depend only on the static ``ConvDims``, so they
are memoized (``functools.lru_cache``): repeated layer shapes -- every step
of a training run retraces the same convs -- skip the VMEM budgeting and tap
enumeration entirely.  ``tile_plan_cache_info()`` exposes hit counts for
tests and debugging; ``clear_tile_plan_cache()`` resets (e.g. after changing
``VMEM_BUDGET_BYTES``).

``interpret`` defaults to True because this container is CPU-only; on real
TPU hardware set ``repro.kernels.ops.INTERPRET = False``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.im2col_ref import ConvDims, rot180, zero_pad
from repro.core import phase_decomp

INTERPRET = True
VMEM_BUDGET_BYTES = 14 * 1024 * 1024
_ELEM_BYTES = 4            # budget in f32 elements (worst case)


# ---------------------------------------------------------------------------
# Layout helpers
# ---------------------------------------------------------------------------

def _to_nhwc(x):
    return x.transpose(0, 2, 3, 1)


def _from_nhwc(x):
    return x.transpose(0, 3, 1, 2)


def _pad_channels(x, mult):
    c = x.shape[-1]
    cp = -(-c // mult) * mult
    if cp == c:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, cp - c)])


def _channel_tile(c: int) -> tuple[int, int]:
    """(padded_c, tile): no padding below 128 channels, 128-tiles above."""
    if c <= 128:
        return c, c
    cp = -(-c // 128) * 128
    return cp, 128


def _phase_split(xp: jax.Array, S: int) -> jax.Array:
    """(B, Hp, Wp, C) -> (S*S, B, ceil(Hp/S), ceil(Wp/S), C) phase planes."""
    b, hp, wp, c = xp.shape
    hp2 = -(-hp // S) * S
    wp2 = -(-wp // S) * S
    xp = jnp.pad(xp, ((0, 0), (0, hp2 - hp), (0, wp2 - wp), (0, 0)))
    xp = xp.reshape(b, hp2 // S, S, wp2 // S, S, c)
    return xp.transpose(2, 4, 0, 1, 3, 5).reshape(S * S, b, hp2 // S, wp2 // S, c)


# ---------------------------------------------------------------------------
# Memoized tile-size / tap-table selection (static per ConvDims)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TilePlan:
    """One Pallas dispatch: channel tiling, tap table, VMEM verdict."""
    fits: bool
    cin_pad: int
    cin_tile: int
    cout_pad: int
    cout_tile: int
    taps: tuple[tuple[int, int, int], ...]
    bytes_needed: int


@dataclasses.dataclass(frozen=True)
class PhasePlan:
    """Input-grad dispatch geometry for one output stride phase."""
    r_h: int
    r_w: int
    c_h: int
    c_w: int
    m_h: int
    m_w: int
    n_qh: int
    n_qw: int
    crop_h: int
    crop_w: int
    pad_lo_h: int
    pad_lo_w: int
    pad_hi_h: int
    pad_hi_w: int
    plan: TilePlan


def _phase_plane_hw(d: ConvDims) -> tuple[int, int]:
    """Spatial extent of one phase plane of the padded input."""
    hp = d.H_i + d.P_h + d.p_h_hi
    wp = d.W_i + d.P_w + d.p_w_hi
    return -(-hp // d.S), -(-wp // d.S)


def _forward_taps(d: ConvDims) -> tuple[tuple[int, int, int], ...]:
    """Kernel tap (kh, kw) -> (phase plane, du, dv) over the split input."""
    return tuple(((kh % d.S) * d.S + (kw % d.S), kh // d.S, kw // d.S)
                 for kh in range(d.K_h) for kw in range(d.K_w))


@functools.lru_cache(maxsize=4096)
def forward_plan(d: ConvDims) -> TilePlan:
    cin_p, cin_t = _channel_tile(d.C)
    cout_p, cout_t = _channel_tile(d.N)
    taps = _forward_taps(d)
    hps, wps = _phase_plane_hw(d)
    bytes_needed = (d.S * d.S * hps * wps * cin_t * _ELEM_BYTES
                    + len(taps) * cin_t * cout_t * _ELEM_BYTES
                    + 2 * d.H_o * d.W_o * cout_t * _ELEM_BYTES)
    return TilePlan(bytes_needed <= VMEM_BUDGET_BYTES, cin_p, cin_t,
                    cout_p, cout_t, taps, bytes_needed)


@functools.lru_cache(maxsize=4096)
def weight_grad_plan(d: ConvDims) -> TilePlan:
    cin_p, cin_t = _channel_tile(d.C)
    cout_p, cout_t = _channel_tile(d.N)
    taps = _forward_taps(d)
    hps, wps = _phase_plane_hw(d)
    bytes_needed = (d.S * d.S * hps * wps * cin_t * _ELEM_BYTES
                    + d.H_o * d.W_o * cout_t * _ELEM_BYTES
                    + len(taps) * cin_t * cout_t * _ELEM_BYTES)
    return TilePlan(bytes_needed <= VMEM_BUDGET_BYTES, cin_p, cin_t,
                    cout_p, cout_t, taps, bytes_needed)


@functools.lru_cache(maxsize=4096)
def input_grad_plan(d: ConvDims) -> tuple[PhasePlan, ...] | None:
    """Per-phase dispatch plans, or None if any phase exceeds the VMEM
    budget (the whole op then falls back to the jnp phase decomposition)."""
    a_h, a_w = d.K_h - 1 - d.P_h, d.K_w - 1 - d.P_w
    cin_p, cin_t = _channel_tile(d.N)      # contraction dim = N
    cout_p, cout_t = _channel_tile(d.C)
    phases = []
    for r_h in range(min(d.S, d.H_i)):
        c_h, m_h, off_h, n_qh = phase_decomp._phase_geometry(
            r_h, a_h, d.S, d.K_h, d.H_i, d.H_o)
        for r_w in range(min(d.S, d.W_i)):
            c_w, m_w, off_w, n_qw = phase_decomp._phase_geometry(
                r_w, a_w, d.S, d.K_w, d.W_i, d.W_o)
            if n_qh == 0 or n_qw == 0 or m_h == 0 or m_w == 0:
                continue
            crop_h, crop_w = max(0, off_h), max(0, off_w)
            pad_lo_h, pad_lo_w = max(0, -off_h), max(0, -off_w)
            pad_hi_h = max(0, (n_qh - 1) + off_h + m_h - d.H_o)
            pad_hi_w = max(0, (n_qw - 1) + off_w + m_w - d.W_o)
            rows = d.H_o - crop_h + pad_lo_h + pad_hi_h
            cols = d.W_o - crop_w + pad_lo_w + pad_hi_w
            taps = tuple((0, mh, mw)
                         for mh in range(m_h) for mw in range(m_w))
            bytes_needed = (rows * cols * cin_t * _ELEM_BYTES
                            + len(taps) * cin_t * cout_t * _ELEM_BYTES
                            + 2 * n_qh * n_qw * cout_t * _ELEM_BYTES)
            plan = TilePlan(bytes_needed <= VMEM_BUDGET_BYTES, cin_p, cin_t,
                            cout_p, cout_t, taps, bytes_needed)
            if not plan.fits:
                return None
            phases.append(PhasePlan(r_h, r_w, c_h, c_w, m_h, m_w, n_qh, n_qw,
                                    crop_h, crop_w, pad_lo_h, pad_lo_w,
                                    pad_hi_h, pad_hi_w, plan))
    return tuple(phases)


_PLANNERS = (forward_plan, weight_grad_plan, input_grad_plan)


def tile_plan_cache_info() -> dict[str, object]:
    """lru_cache stats per planner (hits prove trace-time memoization)."""
    return {p.__wrapped__.__name__: p.cache_info() for p in _PLANNERS}


def clear_tile_plan_cache() -> None:
    for p in _PLANNERS:
        p.cache_clear()


# ---------------------------------------------------------------------------
# Forward convolution (implicit im2col, phase-split tap GEMM)
# ---------------------------------------------------------------------------

def conv2d_forward(x: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    from repro.kernels import tap_gemm as tg
    plan = forward_plan(d)
    if not plan.fits:
        return jax.lax.conv_general_dilated(
            x, w, (d.S, d.S), [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)
    src = _phase_split(_to_nhwc(xp), d.S)            # (S*S, B, HpS, WpS, C)
    src = _pad_channels(src, plan.cin_pad if plan.cin_pad == d.C else 128)
    wt = w.transpose(2, 3, 1, 0).reshape(d.K_h * d.K_w, d.C, d.N)
    wt = _pad_channels(wt.transpose(0, 2, 1),
                       plan.cin_pad if plan.cin_pad == d.C else 128)
    wt = _pad_channels(wt.transpose(0, 2, 1),
                       plan.cout_pad if plan.cout_pad == d.N else 128)
    y = tg.tap_gemm(src, wt, plan.taps, d.H_o, d.W_o,
                    cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
                    out_dtype=x.dtype, interpret=INTERPRET)
    return _from_nhwc(y[..., :d.N])


# ---------------------------------------------------------------------------
# Input gradient (transposed mode): one tap-GEMM per output stride phase
# ---------------------------------------------------------------------------

def conv2d_input_grad(dy: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    from repro.kernels import tap_gemm as tg
    phases = input_grad_plan(d)
    if phases is None:
        return phase_decomp.input_grad_phase(dy, w, d)
    wf = rot180(w)                                       # (N, C, K_h, K_w)
    dyn = _to_nhwc(dy)                                   # (B, Ho, Wo, N)
    di = jnp.zeros((d.B, d.H_i, d.W_i, d.C), dtype=dy.dtype)
    for ph in phases:
        plan = ph.plan
        wk = wf[:, :, ph.c_h::d.S, ph.c_w::d.S][:, :, :ph.m_h, :ph.m_w]
        wk = wk.transpose(2, 3, 0, 1).reshape(ph.m_h * ph.m_w, d.N, d.C)
        wk = _pad_channels(
            wk.transpose(0, 2, 1),
            plan.cin_pad if plan.cin_pad == d.N else 128).transpose(0, 2, 1)
        wk = _pad_channels(wk, plan.cout_pad if plan.cout_pad == d.C else 128)
        src = dyn[:, ph.crop_h:, ph.crop_w:, :]
        src = jnp.pad(src, ((0, 0), (ph.pad_lo_h, ph.pad_hi_h),
                            (ph.pad_lo_w, ph.pad_hi_w), (0, 0)))
        src = _pad_channels(src,
                            plan.cin_pad if plan.cin_pad == d.N else 128)[None]
        out = tg.tap_gemm(src, wk, plan.taps, ph.n_qh, ph.n_qw,
                          cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
                          out_dtype=dy.dtype, interpret=INTERPRET)
        di = di.at[:, ph.r_h::d.S, ph.r_w::d.S, :].set(out[..., :d.C])
    return _from_nhwc(di)


# ---------------------------------------------------------------------------
# Weight gradient (dilated mode): strided-view tap GEMM, batch-accumulated
# ---------------------------------------------------------------------------

def conv2d_weight_grad(x: jax.Array, dy: jax.Array, d: ConvDims) -> jax.Array:
    from repro.kernels import tap_gemm as tg
    plan = weight_grad_plan(d)
    if not plan.fits:
        return phase_decomp.weight_grad_phase(x, dy, d)
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)
    src = _phase_split(_to_nhwc(xp), d.S)
    src = _pad_channels(src, plan.cin_pad if plan.cin_pad == d.C else 128)
    dyn = _pad_channels(_to_nhwc(dy),
                        plan.cout_pad if plan.cout_pad == d.N else 128)
    dw = tg.tap_wgrad(src, dyn, plan.taps, d.H_o, d.W_o,
                      cin_tile=plan.cin_tile, cout_tile=plan.cout_tile,
                      interpret=INTERPRET)
    dw = dw[:, :d.C, :d.N].reshape(d.K_h, d.K_w, d.C, d.N)
    return dw.transpose(3, 2, 0, 1).astype(x.dtype)
