"""Flash attention Pallas kernel (online-softmax, causal/full).

Perf-critical layer for the LM-family architectures: O(L) memory attention
with block-wise online softmax.  Grid (batch*heads, q_blocks); the kernel
scans key/value blocks with a fori_loop keeping running max / normalizer /
weighted accumulator in VMEM scratch.  GQA is handled by the wrapper
(`mha`) which maps query-head groups onto shared KV heads before the call.

Validated against kernels.ref.flash_attention_ref in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_k: int, seq_k_valid: int,
                  q_offset: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale            # (block_q, d)

    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    k_steps = seq_k // block_k

    def body(kv_i, _):
        k_blk = k_ref[0, pl.dslice(kv_i * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.dslice(kv_i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_blk.T                                  # (block_q, block_k)
        k_pos = kv_i * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k_valid                       # padded keys
        if causal:
            q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v_blk
        m_ref[...] = m_new
        return ()

    if causal:
        # Only scan kv blocks that intersect the causal triangle.
        hi = jnp.minimum(
            k_steps,
            (q_offset + (qi + 1) * block_q + block_k - 1) // block_k)
        jax.lax.fori_loop(0, hi, body, ())
    else:
        jax.lax.fori_loop(0, k_steps, body, ())

    o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q,k,v: (B, H, L, D) -> (B, H, L, D). L padded to block multiples."""
    b, h, lq, dd = q.shape
    lk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dd ** 0.5)
    block_q = min(block_q, max(8, lq))
    block_k = min(block_k, max(8, lk))
    lqp = -(-lq // block_q) * block_q
    lkp = -(-lk // block_k) * block_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, lqp - lq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, lkp - lk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, lkp - lk), (0, 0)))
    qp = qp.reshape(b * h, lqp, dd)
    kp = kp.reshape(b * h, lkp, dd)
    vp = vp.reshape(b * h, lkp, dd)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_k=lkp,
        seq_k_valid=lk, q_offset=lk - lq, causal=causal, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, lqp // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, dd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, lkp, dd), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, lkp, dd), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lqp, dd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(b, h, lqp, dd)[:, :, :lq, :]
