"""Tiled MXU GEMM Pallas kernel (generic building block).

Used by the explicit-im2col baseline benchmark path and exercised directly by
kernel tests.  Grid (m, n, k) with f32 VMEM accumulation over the k steps;
tiles default to the MXU-native 128 x 128 x 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a: jax.Array, b: jax.Array, *,
           bm: int = 128, bn: int = 128, bk: int = 128,
           out_dtype=None, interpret: bool = True) -> jax.Array:
    """a (M, K) @ b (K, N); M/N/K padded up to tile multiples internally."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out_dtype = out_dtype or a.dtype
    kernel = functools.partial(_matmul_kernel, k_steps=kp // bk)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
