"""Pure-jnp oracles for every Pallas kernel (the `ref.py` contract).

Each function computes the exact same math as its kernel counterpart with
plain jax.numpy / lax ops; tests sweep shapes, strides and dtypes asserting
allclose between kernel (interpret=True) and oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.im2col_ref import ConvDims, conv2d_lax, conv_grads_lax


def conv2d_forward_ref(x, w, d: ConvDims):
    return conv2d_lax(x, w, d)


def conv2d_input_grad_ref(x, w, dy, d: ConvDims):
    return conv_grads_lax(x, w, dy, d)[0]


def conv2d_weight_grad_ref(x, w, dy, d: ConvDims):
    return conv_grads_lax(x, w, dy, d)[1]


def tap_gemm_ref(src, w, taps, oh, ow):
    """Oracle for kernels.tap_gemm: dense multi-tap GEMM."""
    p_, b_, hs, ws, cin = src.shape
    t_, _, cout = w.shape
    out = jnp.zeros((b_, oh, ow, cout), jnp.float32)
    for t, (p, du, dv) in enumerate(taps):
        xs = src[p, :, du:du + oh, dv:dv + ow, :].astype(jnp.float32)
        out = out + jnp.einsum("bhwc,cn->bhwn", xs, w[t].astype(jnp.float32))
    return out.astype(src.dtype)


def tap_wgrad_ref(src, dy, taps, oh, ow):
    """Oracle for kernels.tap_wgrad."""
    t_ = len(taps)
    cin = src.shape[-1]
    cout = dy.shape[-1]
    out = jnp.zeros((t_, cin, cout), jnp.float32)
    for t, (p, du, dv) in enumerate(taps):
        xs = src[p, :, du:du + oh, dv:dv + ow, :].astype(jnp.float32)
        out = out.at[t].set(
            jnp.einsum("bhwc,bhwn->cn", xs, dy.astype(jnp.float32)))
    return out


def matmul_ref(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """(B, H, L, D) reference attention with optional causal mask."""
    b, h, lq, dd = q.shape
    lk = k.shape[2]
    scale = scale if scale is not None else 1.0 / (dd ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
