"""Measured autotuning of tap-GEMM tile plans, with a persistent cache.

The analytic planner in :mod:`repro.kernels.ops` minimizes a bytes-moved
model under the VMEM budget -- but analytic cost models routinely
mispredict on real matmul accelerators (the plan that moves the fewest
bytes is often not the fastest).  When ``repro.config.autotune`` is
enabled, the planners route through :func:`tuned_plan`:

    analytic plan (feasibility + event accounting stay analytic)
      -> in-process memo
      -> persistent JSON plan cache (key: schema | role | platform |
         interpret | budget | ConvDims) -> revalidate via
         ``ops.plan_from_tile`` (geometry/budget drift => "stale")
      -> mode "measure": time the top-k analytic candidates on device
         (warmup + best-of-reps around ``block_until_ready``), persist the
         winner atomically;
         mode "cached": never time -- persisted winners when present,
         the analytic plan otherwise.

The cache file lives next to jax's compilation cache by default
(``config.plan_cache_dir`` overrides), is written atomically
(tmp + ``os.replace``), and tolerates corrupt files and stale entries:
a bad entry re-tunes, it never crashes.  Timing is interpret-mode aware:
under ``config.interpret`` the numbers measure the CPU interpreter (only
useful to exercise the full path in CI), on a real TPU they measure the
Mosaic-compiled kernels.

Every resolution is observable: plans carry ``autotuned`` /
``measured_us`` / ``candidates_timed`` / ``cache``
(``hit|miss|stale|poisoned``), surfaced by ``ops.plan_report`` and counted
in ``ops.plan_events()`` as ``{role}_autotune_{hit,miss,stale,poisoned,
measure_failed}``.  A runtime engine failure poison-marks its entry
(:func:`poison_plan`) so ``autotune="cached"`` cannot re-crash on restart;
a candidate that crashes while being timed is skipped, never fatal.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
import warnings

import jax
import jax.numpy as jnp

from repro.core.config import config
from repro.core.im2col_ref import ConvDims
from repro.ft.inject import InjectedFault, fault_point
from repro.kernels import ops
from repro.obs import trace as obs_trace

#: bump when the key layout or entry payload changes; older files are
#: ignored wholesale (equivalent to a cold cache).
CACHE_SCHEMA = 1

_CACHE_FILE = "plan_cache.json"

#: key -> fully annotated plan; dropped by config changes (clear_memo).
_MEMO: dict[str, object] = {}


def clear_memo() -> None:
    """Drop the in-process tuned-plan memo (NOT the on-disk cache)."""
    _MEMO.clear()


# ---------------------------------------------------------------------------
# Persistent store
# ---------------------------------------------------------------------------

def default_cache_dir() -> str:
    """``config.plan_cache_dir`` when set, else a ``repro_plan_cache``
    directory next to jax's compilation cache."""
    if config.plan_cache_dir is not None:
        return config.plan_cache_dir
    base = getattr(jax.config, "jax_compilation_cache_dir", None)
    if not base:
        base = os.path.join(os.path.expanduser("~"), ".cache", "jax")
    return os.path.join(base, "repro_plan_cache")


def cache_path() -> str:
    return os.path.join(default_cache_dir(), _CACHE_FILE)


def _load_store() -> dict:
    """The on-disk store, or a fresh one on any read/parse/schema problem
    (a corrupt cache is a cold cache, never an error)."""
    try:
        fault_point("plan_cache.read")
        with open(cache_path(), encoding="utf-8") as f:
            store = json.load(f)
        if (isinstance(store, dict) and store.get("schema") == CACHE_SCHEMA
                and isinstance(store.get("entries"), dict)):
            return store
    except (OSError, ValueError, InjectedFault):
        pass
    return {"schema": CACHE_SCHEMA, "entries": {}}


def _save_store(store: dict) -> None:
    """Atomic best-effort write (tmp + ``os.replace``); an unwritable
    cache dir degrades to tuning every process, not to a crash."""
    path = cache_path()
    try:
        fault_point("plan_cache.write")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(store, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except (OSError, InjectedFault) as e:
        warnings.warn(f"plan cache not persisted ({e}); will re-tune next "
                      f"process", RuntimeWarning, stacklevel=2)


def plan_key(role: str, d: ConvDims, budget: int) -> str:
    """Stable identity of one planning problem.  Platform and interpret
    mode are part of the key: a plan timed on the CPU interpreter must
    never be served to a TPU run (and vice versa)."""
    dims = ",".join(f"{f.name}={getattr(d, f.name)}"
                    for f in dataclasses.fields(d))
    return (f"v{CACHE_SCHEMA}|{role}|{jax.default_backend()}"
            f"|interpret={int(bool(config.interpret))}|budget={budget}"
            f"|{dims}")


# ---------------------------------------------------------------------------
# Timing harness
# ---------------------------------------------------------------------------

def _run_fn(role: str, d: ConvDims, plan):
    """A jitted zero-arg closure running one conv pass under ``plan``.
    Dummy operands: timing is data-independent."""
    x = jnp.ones((d.B, d.C, d.H_i, d.W_i), jnp.float32)
    w = jnp.ones((d.N, d.C, d.k_taps_h, d.k_taps_w), jnp.float32)
    dy = jnp.ones((d.B, d.N, d.H_o, d.W_o), jnp.float32)
    if role == "forward":
        f = jax.jit(lambda a, b: ops.conv2d_forward(a, b, d, plan=plan))
        return lambda: f(x, w)
    if role == "weight_grad":
        f = jax.jit(lambda a, b: ops.conv2d_weight_grad(a, b, d, plan=plan))
        return lambda: f(x, dy)
    if role == "input_grad":
        f = jax.jit(lambda a, b: ops.conv2d_input_grad(a, b, d, plan=plan))
        return lambda: f(dy, w)
    raise ValueError(
        f"unknown plan role {role!r}; roles: {ops.PLAN_ROLES}")


def measure_plan(role: str, d: ConvDims, plan,
                 reps: int | None = None, warmup: int = 1) -> float:
    """Best-of-``reps`` wall time of one conv pass in MICROSECONDS, after
    ``warmup`` untimed calls (absorbing compilation).  Each call is fenced
    with ``block_until_ready`` so async dispatch cannot flatter a plan."""
    fault_point("autotune.measure")
    reps = config.autotune_reps if reps is None else reps
    with obs_trace.span(
            "autotune:measure", role=role, reps=reps,
            dims=[d.B, d.C, d.H_i, d.W_i, d.N, d.K_h, d.K_w]):
        fn = _run_fn(role, d, plan)
        for _ in range(max(1, warmup)):
            jax.block_until_ready(fn())
        best = float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
    return best * 1e6


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _tile_of(plan) -> ops.TilePlan:
    return plan.tile if isinstance(plan, ops.PhasePlan) else plan


def _annotate(plan, **kw):
    """A copy of ``plan`` with autotune provenance fields set (on the
    inner tile for a PhasePlan -- that is what plan_report renders)."""
    if isinstance(plan, ops.PhasePlan):
        return dataclasses.replace(
            plan, tile=dataclasses.replace(plan.tile, **kw))
    return dataclasses.replace(plan, **kw)


def tuned_plan(role: str, d: ConvDims, budget: int, analytic):
    """The tuned (or cache-served, or annotated-analytic) plan for one
    planning problem.  ``analytic`` is the already-resolved analytic plan
    and is always feasible here (``ops._autotuned`` never routes
    fits=False / None plans -- there is nothing to race)."""
    key = plan_key(role, d, budget)
    hit = _MEMO.get(key)
    if hit is not None:
        return hit

    store = _load_store()
    entry = store["entries"].get(key)
    state = "miss"
    if entry is not None and entry.get("poisoned"):
        # A runtime engine failure poison-marked this entry (conv.py's
        # degradation layer): never serve the persisted tile again.  In
        # "cached" mode degrade to the analytic plan; "measure" mode
        # re-tunes, and the fresh winner overwrites the poison mark.
        ops._count_event(f"{role}_autotune_poisoned")
        if config.autotune != "measure":
            plan = _annotate(analytic, cache="poisoned")
            _MEMO[key] = plan
            return plan
        entry = None
        state = "poisoned"
    if entry is not None:
        plan = ops.plan_from_tile(role, d, budget, entry.get("tile", ()))
        if plan is not None:
            plan = _annotate(
                plan, autotuned=True,
                measured_us=float(entry.get("measured_us", -1.0)),
                candidates_timed=int(entry.get("candidates_timed", 0)),
                cache="hit")
            ops._count_event(f"{role}_autotune_hit")
            _MEMO[key] = plan
            return plan
        state = "stale"                   # geometry/budget drift or garbage
    if state != "poisoned":               # poisoned already counted above
        ops._count_event(f"{role}_autotune_{state}")

    if config.autotune != "measure":      # "cached": never time
        plan = _annotate(analytic, cache=state)
        _MEMO[key] = plan
        return plan

    cands = ops.plan_candidates(role, d, budget, k=config.autotune_top_k)
    if not cands:                         # defensive; analytic was feasible
        cands = [analytic]
    best, best_us, timed = None, float("inf"), 0
    for cand in cands:
        try:
            us = measure_plan(role, d, cand)
        except Exception:
            # A candidate that crashes (lowering error, injected fault)
            # must not kill tuning for the whole problem: skip it.
            ops._count_event(f"{role}_autotune_measure_failed")
            continue
        timed += 1
        if us < best_us:
            best, best_us = cand, us
    if best is None:                      # every candidate crashed
        plan = _annotate(analytic, cache=state)
        _MEMO[key] = plan
        return plan
    best = _annotate(best, autotuned=True, measured_us=best_us,
                     candidates_timed=timed, cache=state)
    store["entries"][key] = {
        "tile": list(_tile_of(best).tile_key),
        "measured_us": best_us,
        "candidates_timed": timed,
    }
    _save_store(store)
    _MEMO[key] = best
    return best


def poison_plan(role: str, d: ConvDims, budget: int | None = None) -> str:
    """Poison-mark the persisted plan-cache entry of one planning problem.

    Called by the runtime-degradation layer (``core/conv.py``) when a
    pallas engine execution raises: whatever plan served that launch must
    not be served again on restart -- ``autotune="cached"`` degrades to
    the analytic plan for the key, ``autotune="measure"`` re-tunes (a
    successful fresh measurement overwrites the mark, which is the
    recovery path).  Returns the poisoned key.
    """
    if budget is None:
        budget = config.vmem_budget_bytes
    key = plan_key(role, d, budget)
    _MEMO.pop(key, None)
    store = _load_store()
    entry = store["entries"].get(key) or {}
    store["entries"][key] = {**entry, "poisoned": True}
    _save_store(store)
    return key
