"""Core: the paper's contribution — BP-im2col implicit backprop lowering."""

from repro.core.im2col_ref import ConvDims
from repro.core.conv import (MODES, conv1d, conv1d_causal, conv2d,
                             depthwise_causal_conv1d, make_dims)

__all__ = ["ConvDims", "MODES", "conv2d", "conv1d", "conv1d_causal",
           "depthwise_causal_conv1d", "make_dims"]
