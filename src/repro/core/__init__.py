"""Core: the paper's contribution — BP-im2col implicit backprop lowering."""

from repro.core.im2col_ref import ConvDims
from repro.core.convspec import (ConvSpec, ConvTransposeSpec, EnginePolicy,
                                 PASSES)
from repro.core.conv import (MODES, conv1d, conv1d_causal, conv2d,
                             conv2d_transpose,
                             conv2d_transpose_materialized,
                             conv_policy, conv_transpose_output_shape,
                             depthwise_causal_conv1d,
                             dispatch_events, make_dims, policy_decisions,
                             policy_report, quarantined_engines,
                             register_engine, reset_dispatch_events,
                             resolve_policy, runtime_failures, spec_dims,
                             transpose_dims, transpose_tap_counts)

__all__ = ["ConvDims", "ConvSpec", "ConvTransposeSpec", "EnginePolicy",
           "PASSES", "MODES",
           "conv2d", "conv2d_transpose", "conv2d_transpose_materialized",
           "conv1d", "conv1d_causal", "depthwise_causal_conv1d",
           "conv_policy", "conv_transpose_output_shape", "dispatch_events",
           "policy_decisions", "quarantined_engines",
           "reset_dispatch_events", "resolve_policy", "runtime_failures",
           "policy_report", "register_engine", "make_dims", "spec_dims",
           "transpose_dims", "transpose_tap_counts"]
