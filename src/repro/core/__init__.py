"""Core: the paper's contribution — BP-im2col implicit backprop lowering."""

from repro.core.im2col_ref import ConvDims
from repro.core.convspec import ConvSpec, EnginePolicy, PASSES
from repro.core.conv import (MODES, conv1d, conv1d_causal, conv2d,
                             conv_policy, depthwise_causal_conv1d,
                             dispatch_events, make_dims, policy_decisions,
                             policy_report, register_engine,
                             reset_dispatch_events, resolve_policy, spec_dims)

__all__ = ["ConvDims", "ConvSpec", "EnginePolicy", "PASSES", "MODES",
           "conv2d", "conv1d", "conv1d_causal", "depthwise_causal_conv1d",
           "conv_policy", "dispatch_events", "policy_decisions",
           "reset_dispatch_events", "resolve_policy", "policy_report",
           "register_engine", "make_dims", "spec_dims"]
