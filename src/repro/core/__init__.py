"""Core: the paper's contribution — BP-im2col implicit backprop lowering."""

from repro.core.im2col_ref import ConvDims
from repro.core.conv import conv2d, conv1d, depthwise_causal_conv1d, make_dims

__all__ = ["ConvDims", "conv2d", "conv1d", "depthwise_causal_conv1d",
           "make_dims"]
