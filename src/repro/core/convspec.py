"""Structured convolution geometry (``ConvSpec``) and per-pass engine
selection (``EnginePolicy``).

These two frozen dataclasses replace the stringly
``conv2d(..., stride=int, padding=..., mode=<engine name>)`` surface:

  * ``ConvSpec`` carries the full geometry of one conv layer -- per-axis
    stride ``(s_h, s_w)``, per-axis dilation, asymmetric padding
    ``((top, bottom), (left, right))``, feature ``groups`` and activation
    ``layout`` (``"NCHW"`` native, ``"NHWC"`` transposed at the dispatch
    boundary).  One spec describes the layer; the engines never re-parse
    loose kwargs.

  * ``EnginePolicy`` names the backprop engine *independently per pass*
    (``forward`` / ``input_grad`` / ``weight_grad``).  Each slot is an
    engine name from the ``repro.core.conv.ENGINES`` registry or ``"auto"``,
    which lets the dispatcher consult the Pallas tile planner and the spec's
    geometry: the paper's point is that the three GEMMs of backprop have
    *different* optimal datapaths, so the policy is the unit of selection,
    not a single mode string.

Both are hashable (they ride as ``jax.custom_vjp`` nondiff arguments and as
jit cache keys) and cheap to construct.  Parsing accepts the CLI grammar

    fwd=pallas,dgrad=auto,wgrad=bp_phase

with the aliases fwd/forward, dgrad/igrad/input_grad/dx and
wgrad/weight_grad/dw, plus the degenerate spellings ``"auto"`` (every pass
auto) and a bare engine name (uniform policy -- the exact semantics of the
deprecated ``mode=`` string).
"""

from __future__ import annotations

import dataclasses

LAYOUTS = ("NCHW", "NHWC")

#: the three lowered GEMMs of one conv layer, in dispatch order.
PASSES = ("forward", "input_grad", "weight_grad")

_PASS_ALIASES = {
    "fwd": "forward", "forward": "forward", "f": "forward",
    "dgrad": "input_grad", "igrad": "input_grad", "input_grad": "input_grad",
    "dx": "input_grad", "di": "input_grad",
    "wgrad": "weight_grad", "weight_grad": "weight_grad", "dw": "weight_grad",
}


def _pair(v, name: str) -> tuple[int, int]:
    """int | (a, b) -> (a, b) with positivity check."""
    if isinstance(v, int):
        v = (v, v)
    a, b = int(v[0]), int(v[1])
    if a < 1 or b < 1:
        raise ValueError(f"{name} must be >= 1, got {(a, b)}")
    return a, b


def _pair0(v, name: str) -> tuple[int, int]:
    """int | (a, b) -> (a, b) allowing zero (output_padding may be 0)."""
    if isinstance(v, int):
        v = (v, v)
    a, b = int(v[0]), int(v[1])
    if a < 0 or b < 0:
        raise ValueError(f"{name} must be >= 0, got {(a, b)}")
    return a, b


def _norm_padding(padding):
    """int | (ph, pw) | ((top, bottom), (left, right)) -> nested tuples."""
    if isinstance(padding, int):
        return (padding, padding), (padding, padding)
    ph, pw = padding
    if isinstance(ph, int):
        ph = (ph, ph)
    if isinstance(pw, int):
        pw = (pw, pw)
    out = (int(ph[0]), int(ph[1])), (int(pw[0]), int(pw[1]))
    if min(out[0] + out[1]) < 0:
        raise ValueError(f"padding must be non-negative, got {out}")
    return out


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Complete static geometry of one convolution.

    Fields are stored fully normalized (every axis pair explicit) so two
    specs spelled differently but geometrically identical compare and hash
    equal -- they share one jit trace and one tile-plan cache entry.
    """

    stride: tuple[int, int] = (1, 1)
    dilation: tuple[int, int] = (1, 1)
    padding: tuple[tuple[int, int], tuple[int, int]] = ((0, 0), (0, 0))
    groups: int = 1
    layout: str = "NCHW"

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got {self.layout!r}")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")

    # -- constructors -----------------------------------------------------

    @classmethod
    def make(cls, stride=1, padding=0, dilation=1, groups: int = 1,
             layout: str = "NCHW") -> "ConvSpec":
        """Normalizing constructor: ints / loose pairs accepted everywhere."""
        return cls(stride=_pair(stride, "stride"),
                   dilation=_pair(dilation, "dilation"),
                   padding=_norm_padding(padding),
                   groups=int(groups), layout=layout)

    @classmethod
    def coerce(cls, value) -> "ConvSpec":
        """ConvSpec | None | dict of make() kwargs -> ConvSpec."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls.make(**value)
        raise TypeError(f"cannot interpret {value!r} as a ConvSpec")

    # -- accessors --------------------------------------------------------

    @property
    def s_h(self) -> int:
        return self.stride[0]

    @property
    def s_w(self) -> int:
        return self.stride[1]

    @property
    def d_h(self) -> int:
        return self.dilation[0]

    @property
    def d_w(self) -> int:
        return self.dilation[1]

    @property
    def symmetric_stride(self) -> bool:
        return self.stride[0] == self.stride[1]

    @property
    def has_dilation(self) -> bool:
        return self.dilation != (1, 1)

    def effective_kernel(self, kh: int, kw: int) -> tuple[int, int]:
        """Dilated kernel extent: K_eff = (K - 1) * D + 1 per axis."""
        return (kh - 1) * self.d_h + 1, (kw - 1) * self.d_w + 1

    def with_layout(self, layout: str) -> "ConvSpec":
        return dataclasses.replace(self, layout=layout)


@dataclasses.dataclass(frozen=True)
class ConvTransposeSpec:
    """Complete static geometry of one TRANSPOSED convolution (lhs dilation
    as a forward layer: decoders, GAN generators, upsampling heads).

    ``stride`` is the *input* (lhs) dilation: the layer inverts the spatial
    down-sampling of a regular conv with this stride, so the zero-spaced
    virtual input has ``s - 1`` zeros between every pair of pixels -- the
    exact zero-space of the paper's loss calculation, here appearing in a
    *forward* pass.  ``padding`` follows the standard transposed-conv
    convention (the padding of the mirror regular conv, i.e. it REMOVES
    ``p`` border rows/cols from the virtual full correlation);
    ``output_padding`` appends extra rows/cols at the bottom/right
    (``0 <= output_padding < stride`` per axis) to disambiguate the output
    size, exactly PyTorch's ``ConvTranspose2d`` semantics.  ``dilation``
    dilates the KERNEL (rhs), independently of the lhs dilation.

    Weights are ``(C_in, C_out/groups, K_h, K_w)`` -- the transposed-conv
    convention, which is *literally* the mirror regular conv's ``OIHW``
    weight read with its in/out channel roles swapped.  The output plane is

        H_out = (H_in - 1)*s_h + K_eff_h - p_lo - p_hi + output_padding_h

    (``K_eff = (K-1)*dilation + 1``), see :meth:`output_shape`.
    """

    stride: tuple[int, int] = (1, 1)
    dilation: tuple[int, int] = (1, 1)
    padding: tuple[tuple[int, int], tuple[int, int]] = ((0, 0), (0, 0))
    output_padding: tuple[int, int] = (0, 0)
    groups: int = 1
    layout: str = "NCHW"

    def __post_init__(self):
        if self.layout not in LAYOUTS:
            raise ValueError(f"layout must be one of {LAYOUTS}, "
                             f"got {self.layout!r}")
        if self.groups < 1:
            raise ValueError(f"groups must be >= 1, got {self.groups}")
        for op, s in zip(self.output_padding, self.stride):
            if not 0 <= op < s:
                raise ValueError(
                    f"output_padding must satisfy 0 <= op < stride per "
                    f"axis, got output_padding={self.output_padding} for "
                    f"stride={self.stride}")

    # -- constructors -----------------------------------------------------

    @classmethod
    def make(cls, stride=1, padding=0, output_padding=0, dilation=1,
             groups: int = 1, layout: str = "NCHW") -> "ConvTransposeSpec":
        """Normalizing constructor: ints / loose pairs accepted everywhere."""
        return cls(stride=_pair(stride, "stride"),
                   dilation=_pair(dilation, "dilation"),
                   padding=_norm_padding(padding),
                   output_padding=_pair0(output_padding, "output_padding"),
                   groups=int(groups), layout=layout)

    @classmethod
    def coerce(cls, value) -> "ConvTransposeSpec":
        """ConvTransposeSpec | None | dict of make() kwargs -> spec."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, dict):
            return cls.make(**value)
        raise TypeError(f"cannot interpret {value!r} as a ConvTransposeSpec")

    # -- accessors --------------------------------------------------------

    @property
    def s_h(self) -> int:
        return self.stride[0]

    @property
    def s_w(self) -> int:
        return self.stride[1]

    @property
    def d_h(self) -> int:
        return self.dilation[0]

    @property
    def d_w(self) -> int:
        return self.dilation[1]

    @property
    def op_h(self) -> int:
        return self.output_padding[0]

    @property
    def op_w(self) -> int:
        return self.output_padding[1]

    @property
    def has_dilation(self) -> bool:
        return self.dilation != (1, 1)

    def effective_kernel(self, kh: int, kw: int) -> tuple[int, int]:
        """Dilated kernel extent: K_eff = (K - 1) * D + 1 per axis."""
        return (kh - 1) * self.d_h + 1, (kw - 1) * self.d_w + 1

    def output_shape(self, h: int, w: int, kh: int, kw: int) \
            -> tuple[int, int]:
        """Spatial output plane for an (h, w) input and a COMPACT
        (kh, kw)-tap kernel."""
        keff_h, keff_w = self.effective_kernel(kh, kw)
        (ph_lo, ph_hi), (pw_lo, pw_hi) = self.padding
        return ((h - 1) * self.s_h + keff_h - ph_lo - ph_hi + self.op_h,
                (w - 1) * self.s_w + keff_w - pw_lo - pw_hi + self.op_w)

    def with_layout(self, layout: str) -> "ConvTransposeSpec":
        return dataclasses.replace(self, layout=layout)


#: sentinel engine name: the dispatcher chooses per pass from the planner.
AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class EnginePolicy:
    """Backprop-engine selection, one slot per conv pass.

    Each slot holds an engine name registered in ``repro.core.conv.ENGINES``
    or ``"auto"``.  ``"auto"`` defers the choice to the dispatcher, which
    consults the spec's geometry and the Pallas tile planner per pass and
    records WHY the engine it picked won (``repro.core.conv.
    policy_decisions()``).
    """

    forward: str = AUTO
    input_grad: str = AUTO
    weight_grad: str = AUTO

    # -- constructors -----------------------------------------------------

    @classmethod
    def uniform(cls, engine: str) -> "EnginePolicy":
        """One engine for all three passes -- the old ``mode=`` semantics."""
        return cls(forward=engine, input_grad=engine, weight_grad=engine)

    @classmethod
    def parse(cls, text: str) -> "EnginePolicy":
        """Parse ``"fwd=pallas,dgrad=auto,wgrad=bp_phase"`` (aliases above;
        unnamed passes default to ``auto``), ``"auto"`` or a bare engine
        name (uniform)."""
        text = text.strip()
        if "=" not in text:
            return cls.uniform(text)
        slots = {}
        for item in text.split(","):
            item = item.strip()
            if not item:
                continue
            if "=" not in item:
                raise ValueError(
                    f"bad policy item {item!r}: expected pass=engine")
            key, engine = (s.strip() for s in item.split("=", 1))
            try:
                canon = _PASS_ALIASES[key]
            except KeyError:
                raise ValueError(
                    f"unknown conv pass {key!r}; use one of "
                    f"{sorted(set(_PASS_ALIASES))}") from None
            if canon in slots:
                raise ValueError(f"duplicate policy slot for {canon!r}")
            slots[canon] = engine
        return cls(**slots)

    @classmethod
    def coerce(cls, value) -> "EnginePolicy":
        """EnginePolicy | engine-name | policy-string | dict | None."""
        if value is None:
            return cls()
        if isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls.parse(value)
        if isinstance(value, dict):
            return cls(**{_PASS_ALIASES[k]: v for k, v in value.items()})
        raise TypeError(f"cannot interpret {value!r} as an EnginePolicy")

    # -- accessors --------------------------------------------------------

    def slot(self, pass_name: str) -> str:
        return getattr(self, _PASS_ALIASES[pass_name])

    def slots(self) -> tuple[tuple[str, str], ...]:
        return tuple((p, getattr(self, p)) for p in PASSES)

    @property
    def is_uniform(self) -> bool:
        return self.forward == self.input_grad == self.weight_grad

    def __str__(self) -> str:
        if self.is_uniform:
            return self.forward
        return (f"fwd={self.forward},dgrad={self.input_grad},"
                f"wgrad={self.weight_grad}")
