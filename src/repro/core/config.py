"""All global runtime configuration for this project: ``repro.config``.

One frozen-by-default singleton (the alpa ``global_env`` idiom) replaces the
env-var knobs that used to be read ad hoc across five modules
(``BPIM2COL_INTERPRET`` in ``kernels/ops.py``, ``REPRO_SSD_CHUNK`` in
``models/mamba2.py``, ``REPRO_BLOCKWISE_THRESHOLD`` in
``models/attention.py``, ``REPRO_SCAN_UNROLL`` / ``REPRO_REMAT`` in
``models/transformer.py`` and ``launch/dryrun.py``):

    from repro.core.config import config        # or: import repro; repro.config

    config.vmem_budget_bytes                    # read anywhere, any time
    config.update(autotune="measure")           # permanent, validated
    with config.override(vmem_budget_bytes=1 << 20):
        ...                                     # scoped, restored on exit

Fields initialize ONCE from the environment (so launcher scripts that export
``REPRO_*`` before python starts keep working unchanged), and direct
attribute assignment raises -- mutation goes through :meth:`GlobalConfig.
update` / :meth:`GlobalConfig.override`, which validate values and
invalidate the tile-plan/autotune caches when a plan-affecting field
(``vmem_budget_bytes``, ``interpret``, the ``autotune*`` family,
``plan_cache_dir``) changes.  That kills the pre-config footgun where
mutating a module global (``ops.VMEM_BUDGET_BYTES``) relied on the lru key
catching the change.

Backward compatibility: mutating the environment AFTER import still works --
each attribute read re-checks the raw env string against the snapshot taken
at init, adopts the new value, and emits a ``DeprecationWarning`` -- but new
code should call ``config.update(...)``.  ``scripts/check_no_raw_mode.py``
lints raw ``os.environ.get("REPRO_*" / "BPIM2COL_*")`` reads out of every
module except this one.
"""

from __future__ import annotations

import contextlib
import dataclasses
import os
import sys
import warnings
from typing import Any, Callable


def _parse_bool(raw: str) -> bool:
    """unset/1/true -> True; 0/false/no/off -> False (BPIM2COL_INTERPRET's
    historical parsing, kept verbatim)."""
    return raw.strip().lower() not in ("0", "false", "no", "off")


def _parse_optional_str(raw: str) -> str | None:
    return raw or None


AUTOTUNE_MODES = ("off", "measure", "cached")


def _check_autotune(v: Any) -> str:
    if v not in AUTOTUNE_MODES:
        raise ValueError(
            f"autotune must be one of {AUTOTUNE_MODES}, got {v!r}")
    return v


def _check_positive_int(name: str) -> Callable[[Any], int]:
    def check(v: Any) -> int:
        if not isinstance(v, int) or isinstance(v, bool) or v < 1:
            raise ValueError(f"{name} must be a positive int, got {v!r}")
        return v
    return check


def _check_bool(v: Any) -> bool:
    if not isinstance(v, bool):
        raise ValueError(f"expected a bool, got {v!r}")
    return v


def _check_optional_str(v: Any) -> str | None:
    if v is not None and not isinstance(v, str):
        raise ValueError(f"expected a str or None, got {v!r}")
    return v


def _check_int_any(v: Any) -> int:
    if not isinstance(v, int) or isinstance(v, bool):
        raise ValueError(f"expected an int, got {v!r}")
    return v


def _check_fault_spec(v: Any) -> str | None:
    """Validate the grammar BEFORE the value is stored, so a bad spec fails
    the update() cleanly (imported here, not at module top: config must
    stay importable before -- and without -- the ft stack)."""
    v = _check_optional_str(v)
    if v:
        from repro.ft.inject import parse_fault_spec
        parse_fault_spec(v)
    return v


@dataclasses.dataclass(frozen=True)
class _Field:
    env: str                       # the legacy env var this field absorbs
    default: Any
    parse: Callable[[str], Any]    # raw env string -> value
    check: Callable[[Any], Any]    # validate/normalize an update() value
    plan_affecting: bool = False   # True: changing it invalidates plan caches


#: field name -> spec.  The env vars are the DEPRECATED aliases; the field
#: is the source of truth after import.
FIELDS: dict[str, _Field] = {
    # Pallas kernels: interpret mode (CPU) vs Mosaic compile (real TPU).
    "interpret": _Field("BPIM2COL_INTERPRET", True, _parse_bool,
                        _check_bool, plan_affecting=True),
    # Tile-plan search budget: per-grid-step VMEM footprint ceiling.
    "vmem_budget_bytes": _Field("REPRO_VMEM_BUDGET_BYTES", 14 * 1024 * 1024,
                                int, _check_positive_int("vmem_budget_bytes"),
                                plan_affecting=True),
    # Measured autotuning of the tap-GEMM tile plans (kernels/autotune.py):
    #   off     -- analytic first-fit search only (the historical behavior);
    #   measure -- time the top-k analytic candidates on device, persist the
    #              winner in the plan cache, reuse persisted winners;
    #   cached  -- never time: use persisted winners when present, analytic
    #              plans otherwise (production mode: zero tuning cost).
    "autotune": _Field("REPRO_AUTOTUNE", "off", str, _check_autotune,
                       plan_affecting=True),
    "autotune_top_k": _Field("REPRO_AUTOTUNE_TOP_K", 4, int,
                             _check_positive_int("autotune_top_k"),
                             plan_affecting=True),
    "autotune_reps": _Field("REPRO_AUTOTUNE_REPS", 3, int,
                            _check_positive_int("autotune_reps"),
                            plan_affecting=True),
    # Plan-cache directory; None resolves next to jax's compilation cache
    # (see kernels/autotune.py:default_cache_dir).
    "plan_cache_dir": _Field("REPRO_PLAN_CACHE_DIR", None,
                             _parse_optional_str, _check_optional_str,
                             plan_affecting=True),
    # Mamba2 SSD chunk length (intra-chunk quadratic vs inter-chunk linear).
    "ssd_chunk": _Field("REPRO_SSD_CHUNK", 128, int,
                        _check_positive_int("ssd_chunk")),
    # KV length above which prefill attention switches to the blockwise
    # online-softmax scan.
    "blockwise_kv_threshold": _Field("REPRO_BLOCKWISE_THRESHOLD", 1024, int,
                                     _check_positive_int(
                                         "blockwise_kv_threshold")),
    # Layer-scan unroll factor (roofline dry-runs set 9999 so
    # cost_analysis() sees all layers).
    "scan_unroll": _Field("REPRO_SCAN_UNROLL", 1, int,
                          _check_positive_int("scan_unroll")),
    # Remat override: None defers to each ArchConfig.remat; "none"/"block"
    # force the policy globally.
    "remat": _Field("REPRO_REMAT", None, _parse_optional_str,
                    _check_optional_str),
    # Deterministic fault injection (repro.ft.inject): ';'-separated rules
    # '<site-glob>:<action>[@stepN][~pP]', e.g.
    # "pallas.*:raise@step3;grad.values:nan@step5".  None/"" disarms.  The
    # spec grammar is validated by the injector at arm time, so a bad spec
    # fails the update() that sets it (when the injector is importable).
    "fault_spec": _Field("REPRO_FAULT_SPEC", None, _parse_optional_str,
                         _check_fault_spec),
    # Seed of the injector's probability stream (the '~pP' rules).
    "fault_seed": _Field("REPRO_FAULT_SEED", 0, int, _check_int_any),
    # Unified telemetry (repro.obs): the master switch for the structured
    # event bus, the span tracer and the metrics stream.  Off (the
    # default) is today's zero-overhead behavior -- every obs hook is a
    # single ``is None`` check, the ft.inject disarmed idiom.
    "telemetry": _Field("REPRO_TELEMETRY", False, _parse_bool, _check_bool),
    # Perfetto/Chrome trace_event JSON output path (repro.obs.trace).
    # Spans are only recorded when ``telemetry`` is on AND a path is set;
    # the file is written by ``repro.obs.finalize()`` / ``trace.export()``.
    "trace_path": _Field("REPRO_TRACE_PATH", None, _parse_optional_str,
                         _check_optional_str),
    # Per-step metrics JSONL output path (repro.obs.metrics); one line per
    # training step / serve tick, flushed as written.  Active only when
    # ``telemetry`` is on AND a path is set.
    "metrics_path": _Field("REPRO_METRICS_PATH", None, _parse_optional_str,
                           _check_optional_str),
}

#: fields whose change must re-arm the fault injector.
_FAULT_FIELDS = ("fault_spec", "fault_seed")

#: fields whose change must re-sync the telemetry subsystem.
_OBS_FIELDS = ("telemetry", "trace_path", "metrics_path")


def _invalidate_plan_caches() -> None:
    """Drop every memoized tile plan and tuned-plan memo.  Lazy through
    sys.modules: config must stay importable before (and without) the
    kernel stack, and must not create an import cycle with it."""
    ops = sys.modules.get("repro.kernels.ops")
    if ops is not None:
        ops.clear_tile_plan_cache()
    autotune = sys.modules.get("repro.kernels.autotune")
    if autotune is not None:
        autotune.clear_memo()


def _sync_fault_injector(import_now: bool = False) -> None:
    """Re-arm ``repro.ft.inject`` from the current fault fields.  Lazy by
    default (same no-cycle rule as the plan caches); ``import_now`` forces
    the import so an explicit ``update(fault_spec=...)`` validates the
    spec immediately instead of on first fault_point."""
    inject = sys.modules.get("repro.ft.inject")
    if inject is None and import_now:
        import importlib
        inject = importlib.import_module("repro.ft.inject")
    if inject is not None:
        inject.sync_from_config()


def _sync_obs(import_now: bool = False) -> None:
    """Re-sync ``repro.obs`` (event bus / tracer / metrics stream) from the
    current telemetry fields.  Lazy by default (same no-cycle rule as the
    plan caches); ``import_now`` forces the import so an explicit
    ``update(telemetry=True)`` activates the bus immediately."""
    obs = sys.modules.get("repro.obs")
    if obs is None and import_now:
        import importlib
        obs = importlib.import_module("repro.obs")
    if obs is not None:
        obs.sync_from_config()


class GlobalConfig:
    """The global configuration singleton (``repro.config``).

    Frozen by default: ``config.field = x`` raises; go through
    :meth:`update` (permanent) or :meth:`override` (scoped).  Reading a
    field whose legacy env var changed since init adopts the env value with
    a ``DeprecationWarning`` (the post-import env-mutation shim).
    """

    def __init__(self, env: dict | None = None):
        env = os.environ if env is None else env
        object.__setattr__(self, "_env", env)
        values, raws = {}, {}
        for name, f in FIELDS.items():
            raw = env.get(f.env)
            raws[name] = raw
            values[name] = f.default if raw is None else f.parse(raw)
        object.__setattr__(self, "_values", values)
        object.__setattr__(self, "_env_raw", raws)

    # -- reads ------------------------------------------------------------

    def __getattr__(self, name: str):
        f = FIELDS.get(name)
        if f is None:
            raise AttributeError(
                f"repro.config has no field {name!r}; fields: "
                f"{tuple(FIELDS)}")
        raw = self._env[f.env] if f.env in self._env else None
        if raw != self._env_raw[name]:
            warnings.warn(
                f"mutating {f.env} after import is deprecated; use "
                f"repro.config.update({name}=...) instead",
                DeprecationWarning, stacklevel=2)
            self._env_raw[name] = raw
            self._values[name] = f.default if raw is None else f.parse(raw)
            if f.plan_affecting:
                _invalidate_plan_caches()
            if name in _FAULT_FIELDS:
                _sync_fault_injector()
            if name in _OBS_FIELDS:
                _sync_obs()
        return self._values[name]

    def snapshot(self) -> dict[str, Any]:
        """Current value of every field (a plain dict copy)."""
        return {name: getattr(self, name) for name in FIELDS}

    # -- writes -----------------------------------------------------------

    def __setattr__(self, name: str, value) -> None:
        raise AttributeError(
            f"repro.config is frozen; use config.update({name}={value!r}) "
            f"or the config.override(...) context manager")

    def update(self, **kw) -> None:
        """Validated permanent update; invalidates the tile-plan and tuned-
        plan caches when a plan-affecting field actually changes."""
        unknown = set(kw) - set(FIELDS)
        if unknown:
            raise ValueError(
                f"unknown config field(s) {sorted(unknown)}; fields: "
                f"{tuple(FIELDS)}")
        invalidate = resync_faults = resync_obs = False
        for name, value in kw.items():
            f = FIELDS[name]
            value = f.check(value)
            if f.plan_affecting and self._values[name] != value:
                invalidate = True
            if name in _FAULT_FIELDS and self._values[name] != value:
                resync_faults = True
            if name in _OBS_FIELDS and self._values[name] != value:
                resync_obs = True
            self._values[name] = value
            # An explicit update() supersedes the env var: re-snapshot so a
            # subsequent read does not "restore" the stale env value.
            self._env_raw[name] = self._env.get(f.env)
        if invalidate:
            _invalidate_plan_caches()
        if resync_faults:
            _sync_fault_injector(import_now=True)
        if resync_obs:
            _sync_obs(import_now=True)

    @contextlib.contextmanager
    def override(self, **kw):
        """Scoped :meth:`update`: previous values restored on exit (also on
        exception), with the same cache invalidation on both edges."""
        saved = {name: self._values[name] for name in kw}
        self.update(**kw)
        try:
            yield self
        finally:
            self.update(**saved)


#: the singleton.  ``import repro; repro.config`` and
#: ``from repro.core.config import config`` are the same object.
config = GlobalConfig()
