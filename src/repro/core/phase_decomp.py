"""Stride-phase decomposition: the TPU-native form of BP-im2col.

The RTL in the paper skips zero-space *per element* with dynamic NZ detection.
On a TPU the zero pattern of backprop is perfectly periodic (period = forward
stride S in each spatial dim), so the skipping can be resolved *statically*:
group virtual coordinates by phase (h mod S, w mod S) and every phase becomes
a fully dense sub-problem over the COMPACT tensors.  The MXU only ever sees
dense tiles; zero-space is never built, fetched, or stored -- the same
elimination the paper's address generators achieve, moved to trace time.

Derivation (1-D, height; width is identical).  Let a = K_h - 1 - P_h be the
virtual left pad of the zero-spaced loss dY_ei and Wf = rot180(W).  Then

    dI[hi] = sum_kh dY_ei[hi + kh] * Wf[kh]
           = sum_m dY[q + m + off_r] * Wf[c_r + m*S]        (hi = q*S + r)

with  c_r = (a - r) mod S  (the only kernel-tap phase whose product is
non-zero) and  off_r = (r + c_r - a) / S  (an exact integer).  So for each of
the S x S output phases, dI phase (r_h, r_w) is a stride-1 dense correlation
of the compact dY with the static kernel subsample Wf[c_rh::S, c_rw::S].

For the weight gradient, dW[n,c,kh,kw] = sum_{b,oh,ow} dY[b,n,oh,ow] *
I_pad[b,c, S*oh+kh, S*ow+kw]: for each kernel tap this is a dense contraction
against a strided view of the stored input -- the rhs-dilation of Eq. (1)
becomes an index map, never data.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.im2col_ref import ConvDims, rot180, zero_pad


# ---------------------------------------------------------------------------
# Input gradient (loss calculation), phase-decomposed
# ---------------------------------------------------------------------------

def phase_geometry(r: int, a: int, S: int, K: int, H_i: int, H_o: int):
    """Static per-phase geometry: tap start c_r, tap count M_r, input offset
    off_r, and the phase's output length.  Shared with the Pallas planners
    (``repro.kernels.ops``), which fuse all S*S phases into one dispatch."""
    c_r = (a - r) % S
    M_r = (K - c_r + S - 1) // S          # number of taps kh = c_r + m*S < K
    off_r = (r + c_r - a) // S
    n_q = (H_i - r + S - 1) // S          # outputs q with q*S + r < H_i
    return c_r, M_r, off_r, n_q


_phase_geometry = phase_geometry          # back-compat alias


def input_grad_phase(dy: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    """dI via S_h*S_w dense stride-1 convolutions over the compact dY.

    Equivalent to the paper's transposed mode with all zero-space elided.
    The decomposition is separable per axis, so asymmetric forward strides
    (``d.s_h != d.s_w``) simply enumerate S_h x S_w phases.
    """
    s_h, s_w = d.s_h, d.s_w
    if s_h == 1 and s_w == 1:
        # Degenerate: single phase == plain full-padding correlation.
        return _phase_conv(dy, rot180(w), d, 0, 0)
    a_h = d.K_h - 1 - d.P_h
    a_w = d.K_w - 1 - d.P_w
    wf = rot180(w)                                     # (N, C, K_h, K_w)
    di = jnp.zeros((d.B, d.C, d.H_i, d.W_i), dtype=dy.dtype)
    for r_h in range(min(s_h, d.H_i)):
        c_h, m_h, off_h, n_qh = phase_geometry(r_h, a_h, s_h, d.K_h, d.H_i, d.H_o)
        for r_w in range(min(s_w, d.W_i)):
            c_w, m_w, off_w, n_qw = phase_geometry(r_w, a_w, s_w, d.K_w, d.W_i, d.W_o)
            if n_qh == 0 or n_qw == 0:
                continue
            if m_h == 0 or m_w == 0:
                continue  # no taps contribute: this phase of dI stays zero
            # Static kernel subsample for this phase: (N, C, M_h, M_w)
            wk = wf[:, :, c_h::s_h, c_w::s_w][:, :, :m_h, :m_w]
            # dY window for output q starts at q + off: express as padding.
            pad_lo_h = max(0, -off_h)
            pad_lo_w = max(0, -off_w)
            pad_hi_h = max(0, (n_qh - 1) + off_h + m_h - d.H_o)
            pad_hi_w = max(0, (n_qw - 1) + off_w + m_w - d.W_o)
            # Crop any positive leading offset instead of padding negatively.
            crop_h = max(0, off_h)
            crop_w = max(0, off_w)
            src = dy[:, :, crop_h:, crop_w:]
            out = jax.lax.conv_general_dilated(
                src, wk,                               # (N, C, M_h, M_w) IOHW
                window_strides=(1, 1),
                padding=[(pad_lo_h, pad_hi_h), (pad_lo_w, pad_hi_w)],
                dimension_numbers=("NCHW", "IOHW", "NCHW"))
            di = di.at[:, :, r_h::s_h, r_w::s_w].set(
                out[:, :, :n_qh, :n_qw])
    return di


def _phase_conv(dy: jax.Array, wf: jax.Array, d: ConvDims, r_h: int, r_w: int):
    """S == 1 path: ordinary full correlation with pad K-1-P (low side) and
    K-1-P_hi+R (high side, exact for asymmetric padding)."""
    return jax.lax.conv_general_dilated(
        dy, wf,
        window_strides=(1, 1),
        padding=[(d.K_h - 1 - d.P_h, d.K_h - 1 - d.p_h_hi + d.R_h),
                 (d.K_w - 1 - d.P_w, d.K_w - 1 - d.p_w_hi + d.R_w)],
        dimension_numbers=("NCHW", "IOHW", "NCHW"))


# ---------------------------------------------------------------------------
# Weight gradient (gradient calculation), strided-view form
# ---------------------------------------------------------------------------

def weight_grad_phase(x: jax.Array, dy: jax.Array, d: ConvDims) -> jax.Array:
    """dW via K_h*K_w dense contractions against strided views of the input.

    The zero-inserted 'kernel' dY_i of the paper's dilated mode never exists:
    its zero rows/cols correspond to input samples that are simply never read.
    """
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)  # (B, C, Hp, Wp)
    taps = []
    for kh in range(d.K_h):
        row = []
        for kw in range(d.K_w):
            # Strided view: I_pad[:, :, kh + S_h*oh, kw + S_w*ow]
            v = jax.lax.slice(
                xp,
                (0, 0, kh, kw),
                (d.B, d.C, kh + d.s_h * (d.H_o - 1) + 1,
                 kw + d.s_w * (d.W_o - 1) + 1),
                (1, 1, d.s_h, d.s_w))                  # (B, C, H_o, W_o)
            row.append(jnp.einsum("bnhw,bchw->nc", dy, v,
                                  preferred_element_type=jnp.float32))
        taps.append(jnp.stack(row, axis=-1))           # (N, C, K_w)
    return jnp.stack(taps, axis=-2).astype(x.dtype)    # (N, C, K_h, K_w)
