"""Traditional (explicit) im2col with zero-space materialization.

This module is the paper's baseline ("Original" legend): backprop through a
convolutional layer realized by *physically* zero-inserting / zero-padding the
compact tensors, im2col-lowering them into an explicit matrix copy, and running
a GEMM.  It doubles as the executable oracle against which the implicit
BP-im2col paths (`bpim2col.py`, `phase_decomp.py`, Pallas kernels) are tested.

Layout conventions (match the paper):
  inputs    I   : (B, C, H_i, W_i)      NCHW
  kernels   W   : (N, C, K_h, K_w)      OIHW
  outputs   Y   : (B, N, H_o, W_o)

Forward lowering (inference):
  matrix A (dynamic)    : (B*H_o*W_o, C*K_h*K_w)   -- im2col of padded input
  matrix B (stationary) : (C*K_h*K_w, N)           -- reshaped kernel
  Y = A @ B

Loss calculation (transposed conv, Eq. (1) middle):
  dI = conv(zero_insert_pad(dY), rot180(W).swap(N, C)), stride 1.

Gradient calculation (dilated conv, Eq. (1) bottom):
  dW = conv(Tr(pad(I)), Tr(zero_insert(dY))), stride 1 -- contraction over B.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConvDims:
    """Static geometry of one convolutional layer (paper Table I symbols).

    ``P_h``/``P_w`` are the LOW-side (top/left) pads.  The high-side (bottom/
    right) pads default to the same value; set ``P_h_hi``/``P_w_hi`` for
    asymmetric padding (e.g. causal temporal convs pad only the left side).
    All the implicit address mappings depend only on the low-side pad; the
    high side enters through ``H_o``/``W_o`` and the remainders.

    ``S`` is the row stride.  The column stride ``S_w`` defaults to the
    ``-1`` sentinel meaning "same as ``S``" (the paper's square case); the
    per-axis accessors ``s_h``/``s_w`` resolve it.  Every engine --
    including the Algorithm 1/2 gathers and the Pallas tap planners, whose
    tap tables are built independently per axis -- supports ``s_h != s_w``.

    ``D_h``/``D_w`` declare a kernel dilation: ``K_h``/``K_w`` stay the
    EFFECTIVE (zero-dilated) extents, so every output-size formula and
    address mapping below is dilation-oblivious, and the dilation fields
    only say which effective taps are real (positions ``i*D_h``,
    ``j*D_w``).  Engines that materialize the dilated kernel ignore them;
    the Pallas tap tables use them to skip the zero taps outright
    (``k_taps_h * k_taps_w`` real taps instead of ``K_h * K_w``).
    """

    B: int       # batch
    C: int       # input channels
    H_i: int     # input height
    W_i: int     # input width
    N: int       # output channels
    K_h: int     # kernel height (EFFECTIVE extent: (taps-1)*D_h + 1)
    K_w: int     # kernel width  (EFFECTIVE extent: (taps-1)*D_w + 1)
    S: int = 1   # row stride (and column stride when S_w == -1)
    P_h: int = 0
    P_w: int = 0
    P_h_hi: int = -1   # -1: symmetric (same as P_h)
    P_w_hi: int = -1   # -1: symmetric (same as P_w)
    S_w: int = -1      # -1: symmetric (same as S)
    D_h: int = 1       # kernel dilation (1: dense kernel)
    D_w: int = 1

    def __post_init__(self):
        assert self.D_h >= 1 and self.D_w >= 1, (self.D_h, self.D_w)
        assert (self.K_h - 1) % self.D_h == 0 and \
            (self.K_w - 1) % self.D_w == 0, (
            f"effective kernel extent ({self.K_h}, {self.K_w}) is not "
            f"(taps-1)*D + 1 for dilation ({self.D_h}, {self.D_w})")

    @property
    def s_h(self) -> int:
        return self.S

    @property
    def s_w(self) -> int:
        return self.S if self.S_w < 0 else self.S_w

    @property
    def k_taps_h(self) -> int:
        """Real (non-zero) kernel taps along H: the compact kernel height."""
        return (self.K_h - 1) // self.D_h + 1

    @property
    def k_taps_w(self) -> int:
        return (self.K_w - 1) // self.D_w + 1

    @property
    def has_dilation(self) -> bool:
        return self.D_h > 1 or self.D_w > 1

    @property
    def p_h_hi(self) -> int:
        return self.P_h if self.P_h_hi < 0 else self.P_h_hi

    @property
    def p_w_hi(self) -> int:
        return self.P_w if self.P_w_hi < 0 else self.P_w_hi

    @property
    def H_o(self) -> int:
        return (self.H_i + self.P_h + self.p_h_hi - self.K_h) // self.s_h + 1

    @property
    def W_o(self) -> int:
        return (self.W_i + self.P_w + self.p_w_hi - self.K_w) // self.s_w + 1

    # Zero-inserted sizes (Table I): H_o'' / W_o''
    @property
    def H_o2(self) -> int:
        return self.H_o + (self.H_o - 1) * (self.s_h - 1)

    @property
    def W_o2(self) -> int:
        return self.W_o + (self.W_o - 1) * (self.s_w - 1)

    # Zero-inserted AND zero-padded sizes (Table I): H_o''' / W_o'''
    # (+R: general-tiling correction, zero under the paper's assumptions)
    @property
    def H_o3(self) -> int:
        return (self.H_o2 + (self.K_h - 1 - self.P_h)
                + (self.K_h - 1 - self.p_h_hi) + self.R_h)

    @property
    def W_o3(self) -> int:
        return (self.W_o2 + (self.K_w - 1 - self.P_w)
                + (self.K_w - 1 - self.p_w_hi) + self.R_w)

    # Tiling remainder: rows/cols of the input that no forward window covers
    # (the paper's formulas assume R == 0, but its own Table II layer 1,
    # 224/3/64/3/2/0, has R == 1 -- we support the general case).
    @property
    def R_h(self) -> int:
        return (self.H_i + self.P_h + self.p_h_hi - self.K_h
                - (self.H_o - 1) * self.s_h)

    @property
    def R_w(self) -> int:
        return (self.W_i + self.P_w + self.p_w_hi - self.K_w
                - (self.W_o - 1) * self.s_w)

    def validate(self) -> None:
        assert self.H_o >= 1 and self.W_o >= 1
        assert self.K_h - 1 - self.P_h >= 0 and self.K_w - 1 - self.P_w >= 0, (
            "transposed-conv padding K-1-P must be non-negative")
        assert self.K_h - 1 - self.p_h_hi + self.R_h >= 0 and \
            self.K_w - 1 - self.p_w_hi + self.R_w >= 0, (
            "high-side transposed-conv padding K-1-P_hi+R must be "
            "non-negative")

    # ---- element counts used by the perf model and sparsity analysis ----

    def lowered_B_shape_loss(self) -> tuple[int, int]:
        """Virtual stationary matrix B during loss calc: rows x cols."""
        return (self.N * self.K_h * self.K_w, self.B * self.H_i * self.W_i)

    def lowered_A_shape_grad(self) -> tuple[int, int]:
        """Virtual dynamic matrix A during gradient calc (zero-inserted dY)."""
        return (self.B * self.H_o2 * self.W_o2, 1)  # per (n) column stream

    def zero_space_sparsity_loss(self) -> float:
        """Fraction of zero pixels in the zero-spaced dY feature map
        (H_o''' x W_o''') consumed by loss calculation."""
        total = self.H_o3 * self.W_o3
        nonzero = self.H_o * self.W_o
        return 1.0 - nonzero / total

    def zero_space_sparsity_grad(self) -> float:
        """Fraction of zero pixels in the zero-inserted dY (H_o'' x W_o'')."""
        total = self.H_o2 * self.W_o2
        nonzero = self.H_o * self.W_o
        return 1.0 - nonzero / total


# ---------------------------------------------------------------------------
# Zero-space construction (the data reorganization BP-im2col eliminates)
# ---------------------------------------------------------------------------

def zero_insert(x: jax.Array, S) -> jax.Array:
    """Insert S-1 zeros between spatial elements: (..., H, W) -> (..., H'', W'').

    ``S`` is an int (same both dims) or a per-axis pair ``(s_h, s_w)``.
    """
    s_h, s_w = (S, S) if isinstance(S, int) else S
    if s_h == 1 and s_w == 1:
        return x
    *lead, H, W = x.shape
    out = jnp.zeros((*lead, H + (H - 1) * (s_h - 1), W + (W - 1) * (s_w - 1)),
                    dtype=x.dtype)
    return out.at[..., ::s_h, ::s_w].set(x)


def zero_pad(x: jax.Array, ph: int, pw: int, ph_hi: int | None = None,
             pw_hi: int | None = None) -> jax.Array:
    """Spatial zero padding on the last two dims (asymmetric if *_hi given)."""
    ph_hi = ph if ph_hi is None else ph_hi
    pw_hi = pw if pw_hi is None else pw_hi
    if ph == 0 and pw == 0 and ph_hi == 0 and pw_hi == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 2) + [(ph, ph_hi), (pw, pw_hi)]
    return jnp.pad(x, pad)


def zero_insert_pad(dy: jax.Array, d: ConvDims) -> jax.Array:
    """dY (B,N,H_o,W_o) -> zero-spaced dY_ei.

    Pad is K-1-P on top/left and K-1-P_hi+R on bottom/right so that a
    stride-1 valid conv reproduces the full H_i x W_i input gradient (R is
    the forward tiling remainder, zero in the paper's idealized formulas).
    """
    return zero_pad(zero_insert(dy, (d.s_h, d.s_w)),
                    d.K_h - 1 - d.P_h, d.K_w - 1 - d.P_w,
                    d.K_h - 1 - d.p_h_hi + d.R_h,
                    d.K_w - 1 - d.p_w_hi + d.R_w)


def rot180(w: jax.Array) -> jax.Array:
    """Kernel-wise 180-degree rotation on the two trailing spatial dims."""
    return w[..., ::-1, ::-1]


# ---------------------------------------------------------------------------
# Explicit im2col (stride-1 lowering used by all three backprop GEMMs)
# ---------------------------------------------------------------------------

def im2col(x: jax.Array, kh: int, kw: int, stride=1) -> jax.Array:
    """Lower (B, C, H, W) into the dynamic matrix (B*H_o*W_o, C*kh*kw).

    ``stride`` is an int or a per-axis ``(s_h, s_w)`` pair.  This
    materializes the matrix copy -- the storage/bandwidth overhead the
    implicit algorithms avoid.
    """
    s_h, s_w = (stride, stride) if isinstance(stride, int) else stride
    b, c, h, w = x.shape
    ho = (h - kh) // s_h + 1
    wo = (w - kw) // s_w + 1
    # (B, C*kh*kw, ho*wo) patches
    patches = jax.lax.conv_general_dilated_patches(
        x, (kh, kw), (s_h, s_w), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    patches = patches.reshape(b, c * kh * kw, ho * wo)
    return patches.transpose(0, 2, 1).reshape(b * ho * wo, c * kh * kw)


# ---------------------------------------------------------------------------
# Forward / backward by explicit GEMM (the baseline accelerator's behaviour)
# ---------------------------------------------------------------------------

def conv2d_forward_explicit(x: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    """Inference: Y = im2col(pad(I)) @ W  -- traditional im2col."""
    xp = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi)
    a = im2col(xp, d.K_h, d.K_w, (d.s_h, d.s_w))            # (B*Ho*Wo, C*Kh*Kw)
    b = w.reshape(d.N, d.C * d.K_h * d.K_w).T               # (C*Kh*Kw, N)
    y = a @ b                                               # (B*Ho*Wo, N)
    return y.reshape(d.B, d.H_o, d.W_o, d.N).transpose(0, 3, 1, 2)


def input_grad_explicit(dy: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    """Loss calculation with full zero-space materialization.

    dI = conv(dY_ei, Tr(rot180(W))), stride 1.  The zero-spaced dY_ei and its
    im2col copy are both physically built (this is what the paper measures as
    'Reorganization' + 'Computation').
    """
    dy_ei = zero_insert_pad(dy, d)                          # (B,N,Ho''',Wo''')
    wt = rot180(w).transpose(1, 0, 2, 3)                    # (C,N,Kh,Kw)
    a = im2col(dy_ei, d.K_h, d.K_w, 1)                      # (B*Hi*Wi, N*Kh*Kw)
    b = wt.reshape(d.C, d.N * d.K_h * d.K_w).T              # (N*Kh*Kw, C)
    di = a @ b
    return di.reshape(d.B, d.H_i, d.W_i, d.C).transpose(0, 3, 1, 2)


def weight_grad_explicit(x: jax.Array, dy: jax.Array, d: ConvDims) -> jax.Array:
    """Gradient calculation with full zero-space materialization.

    Tr(dW) = conv(Tr(pad(I)), Tr(zero_insert(dY))), stride 1.  The channel/batch
    transposes turn B into the contraction dim and the zero-inserted dY into the
    convolving kernel of size (H_o'', W_o'').
    """
    xe = zero_pad(x, d.P_h, d.P_w, d.p_h_hi, d.p_w_hi).transpose(1, 0, 2, 3)
    # Crop tiling-remainder rows/cols (never touched by any forward window).
    xe = xe[:, :, :d.K_h + (d.H_o - 1) * d.s_h, :d.K_w + (d.W_o - 1) * d.s_w]
    dyi = zero_insert(dy, (d.s_h, d.s_w)).transpose(1, 0, 2, 3)  # (N,B,Ho'',Wo'')
    a = im2col(xe, d.H_o2, d.W_o2, 1)                       # (C*Kh*Kw, B*Ho''*Wo'')
    b = dyi.reshape(d.N, d.B * d.H_o2 * d.W_o2).T           # (B*Ho''*Wo'', N)
    dwt = a @ b                                             # (C*Kh*Kw, N)
    return dwt.reshape(d.C, d.K_h, d.K_w, d.N).transpose(3, 0, 1, 2)


# ---------------------------------------------------------------------------
# Ground truth via lax (used by tests to anchor BOTH baseline and ours)
# ---------------------------------------------------------------------------

def conv2d_lax(x: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w, (d.s_h, d.s_w), [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def conv_grads_lax(x: jax.Array, w: jax.Array, dy: jax.Array, d: ConvDims):
    """(dI, dW) from jax autodiff -- the numeric ground truth."""
    _, vjp = jax.vjp(lambda x_, w_: conv2d_lax(x_, w_, d), x, w)
    return vjp(dy)


# ---------------------------------------------------------------------------
# Byte/element accounting for the perf model (what reorganization costs)
# ---------------------------------------------------------------------------

def reorg_traffic_elems_loss(d: ConvDims) -> dict[str, int]:
    """Elements read+written by the zero-space reorganization of dY for the
    loss calc, and elements streamed to buffer B, under traditional im2col."""
    compact = d.B * d.N * d.H_o * d.W_o
    spaced = d.B * d.N * d.H_o3 * d.W_o3
    lowered = d.N * d.K_h * d.K_w * d.B * d.H_i * d.W_i  # stationary matrix B
    return {
        "reorg_read": compact,
        "reorg_write": spaced,
        "offchip_stream": spaced,       # zero-spaced map shipped to chip
        "buffer_stream": lowered,       # lowered matrix entries fed to PEs
        "extra_storage": spaced - compact,
    }


def reorg_traffic_elems_grad(d: ConvDims) -> dict[str, int]:
    compact = d.B * d.N * d.H_o * d.W_o
    spaced = d.B * d.N * d.H_o2 * d.W_o2
    return {
        "reorg_read": compact,
        "reorg_write": spaced,
        "offchip_stream": spaced,
        "buffer_stream": spaced,        # matrix A rows stream zero-inserted dY
        "extra_storage": spaced - compact,
    }
