"""BP-im2col: implicit im2col address mapping for backpropagation.

This is the paper's core contribution (Section III), implemented as pure
integer index math in JAX.  The hardware address-generation modules become
vectorized functions

    (virtual address) -> (is_nonzero, compact address)

exactly following Algorithm 1 (transposed mode, loss calculation) and
Algorithm 2 (dilated mode, gradient calculation), with the NZ-detection
predicates of Eqs. (2)-(4).

Two consumption styles are provided:

* ``gather_lowered_*`` -- build the lowered GEMM operand by *gathering* only
  from the compact tensor (zeros injected by ``where``).  This is the literal
  software analogue of the RTL datapath: the virtual matrix never exists in
  memory; only compact data is ever read.  It is the executable spec the
  Pallas kernels and phase decomposition are tested against.

* ``input_grad_implicit`` / ``weight_grad_implicit`` -- end-to-end backprop
  results computed through the implicit lowering (gather + GEMM), matching
  ``jax.grad`` of the reference convolution.

Everything is shape-static: the virtual geometry is folded into index arrays
at trace time, so under jit the "address generation" costs nothing at runtime
beyond the gather itself.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.im2col_ref import ConvDims, rot180


# ---------------------------------------------------------------------------
# NZ detection (Eqs. (2)-(4))
# ---------------------------------------------------------------------------

def in_area0_transposed(h, w, d: ConvDims):
    """Eq. (2): upper/left zero-padding area of the zero-spaced dY."""
    return (h < d.K_h - 1 - d.P_h) | (w < d.K_w - 1 - d.P_w)


def in_area1_transposed(h, w, d: ConvDims):
    """Eq. (3): zero-insertion grid + lower/right padding, evaluated
    independently per axis (period = the forward stride of THAT axis, so
    asymmetric strides just use two different moduli).

    The modulo test also covers the lower/right pad because indices past the
    last inserted row map to h' >= H_o, which we guard with a range check.
    """
    hh = h - (d.K_h - 1 - d.P_h)
    ww = w - (d.K_w - 1 - d.P_w)
    return (hh % d.s_h > 0) | (ww % d.s_w > 0)


def nz_transposed(h, w, d: ConvDims):
    """True where the virtual zero-spaced dY pixel (h, w) is NON-zero,
    i.e. fails Eq. (2) and Eq. (3) and lands inside the stored H_o x W_o."""
    hh = h - (d.K_h - 1 - d.P_h)
    ww = w - (d.K_w - 1 - d.P_w)
    hp = hh // d.s_h
    wp = ww // d.s_w
    ok = (~in_area0_transposed(h, w, d)) & (~in_area1_transposed(h, w, d))
    ok &= (hp >= 0) & (hp < d.H_o) & (wp >= 0) & (wp < d.W_o)
    return ok, hp, wp


def nz_dilated(h, w, d: ConvDims):
    """Eq. (4): virtual zero-inserted dY pixel (h, w) is non-zero iff
    h % s_h == 0 and w % s_w == 0; compact position (h/s_h, w/s_w)."""
    ok = (h % d.s_h == 0) & (w % d.s_w == 0)
    hp = h // d.s_h
    wp = w // d.s_w
    ok &= (hp < d.H_o) & (wp < d.W_o)
    return ok, hp, wp


# ---------------------------------------------------------------------------
# Algorithm 1 -- transposed mode address mapping (loss calculation)
# ---------------------------------------------------------------------------

def algorithm1(addr_in: jax.Array, d: ConvDims):
    """Map flat addresses of the virtual stationary matrix B to compact
    addresses in the stored dY (B, N, H_o, W_o), flattened row-major.

    Virtual matrix B has shape (N*K_h*K_w, B*H_i*W_i): entry (row, col) is the
    zero-spaced dY pixel that multiplies kernel tap (h_k, w_k) for output pixel
    (h-ish, w-ish) of sample b.  Returns (valid, addr_out); addr_out is
    poisoned with -1 where the pixel lies in a zero-space (the paper's NULL).
    """
    addr_in = jnp.asarray(addr_in)
    # Algorithm 1 lines 1-4 (integer decode of the virtual coordinate)
    row = addr_in // (d.B * d.H_i * d.W_i)
    col = addr_in % (d.B * d.H_i * d.W_i)
    b = col // (d.H_i * d.W_i)
    temp1 = row // d.K_w
    w_k = row % d.K_w
    n = temp1 // d.K_h
    h_k = temp1 % d.K_h
    temp2 = col % (d.H_i * d.W_i)
    h = temp2 // d.W_i + h_k
    w = temp2 % d.W_i + w_k
    # Lines 5-10: NZ detection + compact mapping
    ok, hp, wp = nz_transposed(h, w, d)
    addr_out = (b * d.N * d.H_o * d.W_o + n * d.H_o * d.W_o
                + hp * d.W_o + wp)
    return ok, jnp.where(ok, addr_out, -1)


def algorithm2(addr_in: jax.Array, d: ConvDims):
    """Map flat addresses of the virtual dynamic matrix A (the zero-inserted
    dY viewed as (N, B, H_o'', W_o'') stream) to compact dY addresses.

    Follows Algorithm 2 of the paper; returns (valid, addr_out) with -1 NULLs.
    """
    addr_in = jnp.asarray(addr_in)
    n = addr_in // (d.B * d.H_o2 * d.W_o2)
    col = addr_in % (d.B * d.H_o2 * d.W_o2)
    temp = col // d.W_o2
    w = col % d.W_o2
    b = temp // d.H_o2
    h = temp % d.H_o2
    ok, hp, wp = nz_dilated(h, w, d)
    addr_out = (b * d.N * d.H_o * d.W_o + n * d.H_o * d.W_o
                + hp * d.W_o + wp)
    return ok, jnp.where(ok, addr_out, -1)


# ---------------------------------------------------------------------------
# Implicit lowered-operand construction (virtual matrix -> gather)
# ---------------------------------------------------------------------------

def gather_lowered_B_loss(dy: jax.Array, d: ConvDims) -> jax.Array:
    """Materialize the lowered stationary matrix B of the loss calculation
    WITHOUT ever building the zero-spaced dY: every entry is either a gather
    from compact dy or an injected zero.  Shape (N*K_h*K_w, B*H_i*W_i).

    (Used as executable spec / CPU path; the Pallas kernel consumes the same
    index map without materializing this matrix either.)
    """
    rows, cols = d.lowered_B_shape_loss()
    addr = jnp.arange(rows * cols, dtype=jnp.int32)
    ok, out = algorithm1(addr, d)
    flat = dy.reshape(-1)
    vals = jnp.where(ok, flat[jnp.clip(out, 0)], 0)
    return vals.reshape(rows, cols).astype(dy.dtype)


def gather_lowered_A_grad(dy: jax.Array, d: ConvDims) -> jax.Array:
    """Materialize the zero-inserted dY stream (N, B*H_o''*W_o'') for the
    gradient calculation via Algorithm 2 gathers (no reorganization)."""
    total = d.N * d.B * d.H_o2 * d.W_o2
    addr = jnp.arange(total, dtype=jnp.int32)
    ok, out = algorithm2(addr, d)
    flat = dy.reshape(-1)
    vals = jnp.where(ok, flat[jnp.clip(out, 0)], 0)
    return vals.reshape(d.N, d.B * d.H_o2 * d.W_o2).astype(dy.dtype)


# ---------------------------------------------------------------------------
# End-to-end implicit backprop (gather + GEMM), the BP-im2col data path
# ---------------------------------------------------------------------------

def input_grad_implicit(dy: jax.Array, w: jax.Array, d: ConvDims) -> jax.Array:
    """Loss calculation via BP-im2col: dI = B_lowered^T-structured GEMM with
    Tr(rot180(W)); only compact dy is ever read.  The Algorithm 1 address
    mapping is per-axis (independent row/column predicates), so asymmetric
    strides work directly; ``w`` is the effective (dense-extent) kernel."""
    assert w.shape[-2:] == (d.K_h, d.K_w)
    bm = gather_lowered_B_loss(dy, d)                 # (N*Kh*Kw, B*Hi*Wi)
    wt = rot180(w).transpose(1, 0, 2, 3)              # (C, N, Kh, Kw)
    wm = wt.reshape(d.C, d.N * d.K_h * d.K_w)         # (C, N*Kh*Kw)
    di = wm @ bm                                      # (C, B*Hi*Wi)
    return (di.reshape(d.C, d.B, d.H_i, d.W_i)
              .transpose(1, 0, 2, 3))


def weight_grad_implicit(x: jax.Array, dy: jax.Array, d: ConvDims) -> jax.Array:
    """Gradient calculation via BP-im2col: matrix A rows are fetched through
    Algorithm 2 (compact dy only); matrix B is the im2col of the padded input
    (same as inference -- no zero-space beyond ordinary padding).  The
    zero-insertion period of the virtual dY is per-axis (s_h rows, s_w
    cols), so asymmetric strides work directly."""
    from repro.core.im2col_ref import im2col, zero_pad
    a = gather_lowered_A_grad(dy, d)                  # (N, B*Ho''*Wo'')
    xe = zero_pad(x, d.P_h, d.P_w,
                  d.p_h_hi, d.p_w_hi).transpose(1, 0, 2, 3)
    xe = xe[:, :, :d.K_h + (d.H_o - 1) * d.s_h,
            :d.K_w + (d.W_o - 1) * d.s_w]
    b = im2col(xe, d.H_o2, d.W_o2, 1)                 # (C*Kh*Kw, B*Ho''*Wo'')
    dwt = b @ a.T                                     # (C*Kh*Kw, N)
    return (dwt.reshape(d.C, d.K_h, d.K_w, d.N)
               .transpose(3, 0, 1, 2))


# ---------------------------------------------------------------------------
# Sparsity / traffic analysis (paper Section II claims, Fig. 8 overlays)
# ---------------------------------------------------------------------------

def lowered_sparsity_loss(d: ConvDims) -> float:
    """Exact fraction of zero entries in the lowered matrix B of the loss
    calc -- the paper reports 75%..93.91% for stride>=2 workloads."""
    rows, cols = d.lowered_B_shape_loss()
    # Count analytically: entry is nonzero iff its virtual (h, w) passes NZ.
    # h = oh + h_k with oh in [0, H_i), h_k in [0, K_h); same for w.
    hs = np.arange(d.H_i)[:, None] + np.arange(d.K_h)[None, :]  # (H_i, K_h)
    ws = np.arange(d.W_i)[:, None] + np.arange(d.K_w)[None, :]
    hh = hs - (d.K_h - 1 - d.P_h)
    ww = ws - (d.K_w - 1 - d.P_w)
    ok_h = (hh >= 0) & (hh % d.s_h == 0) & (hh // d.s_h < d.H_o)
    ok_w = (ww >= 0) & (ww % d.s_w == 0) & (ww // d.s_w < d.W_o)
    nz = ok_h.sum() * ok_w.sum()
    return 1.0 - nz / (rows * cols / d.N / d.B)  # per (n, b) plane ratio


def lowered_sparsity_grad(d: ConvDims) -> float:
    """Fraction of zeros in the zero-inserted dY consumed by the grad calc."""
    return d.zero_space_sparsity_grad()


def bp_traffic_elems_loss(d: ConvDims) -> dict[str, int]:
    """Traffic under BP-im2col for loss calc: no reorganization; off-chip
    streams compact dY; buffer feeds only non-zero lowered entries."""
    compact = d.B * d.N * d.H_o * d.W_o
    rows, cols = d.lowered_B_shape_loss()
    nonzero_lowered = int(round((1.0 - lowered_sparsity_loss(d)) * rows * cols))
    return {
        "reorg_read": 0,
        "reorg_write": 0,
        "offchip_stream": compact,
        "buffer_stream": nonzero_lowered,
        "extra_storage": 0,
    }


def bp_traffic_elems_grad(d: ConvDims) -> dict[str, int]:
    compact = d.B * d.N * d.H_o * d.W_o
    return {
        "reorg_read": 0,
        "reorg_write": 0,
        "offchip_stream": compact,
        "buffer_stream": compact,   # only non-zero rows of matrix A stream
        "extra_storage": 0,
    }
