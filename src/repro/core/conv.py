"""Convolution with a selectable backpropagation engine.

``conv2d(x, w, stride, padding, mode=...)`` computes the same forward result
for every mode; the mode chooses how the backward pass is realized:

  * ``"lax"``         -- XLA's native conv + autodiff (control / ground truth)
  * ``"traditional"`` -- explicit im2col with zero-space materialization (the
                         paper's baseline accelerator behaviour)
  * ``"bp_im2col"``   -- the paper's implicit algorithm: Algorithms 1 & 2
                         address mapping + gather (literal reproduction)
  * ``"bp_phase"``    -- TPU-native stride-phase decomposition (same zero
                         elimination, dense MXU form; the production path)
  * ``"pallas"``      -- Pallas kernels (phase-decomposed GEMMs with explicit
                         VMEM BlockSpecs; interpret=True on CPU)

The mode is a static argument so jit specializes per mode; all modes are
validated against each other in tests/test_conv_modes.py.

Also provides ``conv1d_*`` wrappers (used by the Mamba2 / RecurrentGemma
temporal convolutions) which lower 1-D convs onto the same engines by
treating them as (H=1) 2-D convs, and a depthwise path.
"""

from __future__ import annotations

from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bpim2col, im2col_ref, phase_decomp
from repro.core.im2col_ref import ConvDims

Mode = Literal["lax", "traditional", "bp_im2col", "bp_phase", "pallas"]


def make_dims(x_shape, w_shape, stride: int, padding: tuple[int, int]) -> ConvDims:
    b, c, h, w = x_shape
    n, c2, kh, kw = w_shape
    assert c == c2, f"channel mismatch {c} vs {c2}"
    return ConvDims(B=b, C=c, H_i=h, W_i=w, N=n, K_h=kh, K_w=kw,
                    S=stride, P_h=padding[0], P_w=padding[1])


# ---------------------------------------------------------------------------
# Mode dispatch tables
# ---------------------------------------------------------------------------

def _forward(x, w, d: ConvDims, mode: Mode):
    if mode in ("lax", "bp_phase"):
        return im2col_ref.conv2d_lax(x, w, d)
    if mode == "pallas":
        from repro.kernels import ops
        return ops.conv2d_forward(x, w, d)
    return im2col_ref.conv2d_forward_explicit(x, w, d)


def _input_grad(dy, w, d: ConvDims, mode: Mode):
    if mode == "lax":
        raise AssertionError("lax mode uses native autodiff")
    if mode == "traditional":
        return im2col_ref.input_grad_explicit(dy, w, d)
    if mode == "bp_im2col":
        return bpim2col.input_grad_implicit(dy, w, d)
    if mode == "bp_phase":
        return phase_decomp.input_grad_phase(dy, w, d)
    if mode == "pallas":
        from repro.kernels import ops
        return ops.conv2d_input_grad(dy, w, d)
    raise ValueError(mode)


def _weight_grad(x, dy, d: ConvDims, mode: Mode):
    if mode == "traditional":
        return im2col_ref.weight_grad_explicit(x, dy, d)
    if mode == "bp_im2col":
        return bpim2col.weight_grad_implicit(x, dy, d)
    if mode == "bp_phase":
        return phase_decomp.weight_grad_phase(x, dy, d)
    if mode == "pallas":
        from repro.kernels import ops
        return ops.conv2d_weight_grad(x, dy, d)
    raise ValueError(mode)


# ---------------------------------------------------------------------------
# custom_vjp conv
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           padding: tuple[int, int] = (0, 0), mode: Mode = "bp_phase"):
    """NCHW x OIHW -> NCHW convolution with a selectable backprop engine."""
    d = make_dims(x.shape, w.shape, stride, padding)
    if mode == "lax":
        return im2col_ref.conv2d_lax(x, w, d)
    return _forward(x, w, d, mode)


def _conv2d_fwd(x, w, stride, padding, mode):
    d = make_dims(x.shape, w.shape, stride, padding)
    return _forward(x, w, d, mode), (x, w)


def _conv2d_bwd(stride, padding, mode, res, dy):
    x, w = res
    d = make_dims(x.shape, w.shape, stride, padding)
    if mode == "lax":
        dx, dw = im2col_ref.conv_grads_lax(x, w, dy, d)
    else:
        dx = _input_grad(dy, w, d, mode)
        dw = _weight_grad(x, dy, d, mode)
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


# ---------------------------------------------------------------------------
# 1-D and depthwise wrappers (Mamba2 / RecurrentGemma temporal convs)
# ---------------------------------------------------------------------------

def conv1d(x: jax.Array, w: jax.Array, stride: int = 1, padding: int = 0,
           mode: Mode = "bp_phase") -> jax.Array:
    """(B, C, L) x (N, C, K) -> (B, N, L_o) through the 2-D engines."""
    x4 = x[:, :, None, :]
    w4 = w[:, :, None, :]
    y = conv2d(x4, w4, stride, (0, padding), mode)
    return y[:, :, 0, :]


def depthwise_causal_conv1d(x: jax.Array, w: jax.Array,
                            mode: Mode = "bp_phase") -> jax.Array:
    """Causal depthwise conv used by Mamba2: x (B, L, C), w (K, C).

    Implemented channel-grouped: pad left K-1, each channel convolved with its
    own K-tap filter.  Grouped conv is lowered as feature-dim gather + the
    selected engine on a (B*C, 1, 1, L) view to keep the BP-im2col path
    exercised for the depthwise case too; for speed under jit the lax path
    short-circuits to conv_general_dilated with feature_group_count.
    """
    b, l, c = x.shape
    k = w.shape[0]
    if mode == "lax" or mode == "bp_phase":
        # Production path: grouped conv, causal left pad; backward of a
        # stride-1 conv has no zero-insertion so phase == lax here.
        xt = x.transpose(0, 2, 1)[:, :, None, :]            # (B, C, 1, L)
        wt = w.T[:, None, None, :]                          # (C, 1, 1, K)
        y = jax.lax.conv_general_dilated(
            xt, wt, (1, 1), [(0, 0), (k - 1, 0)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=c)
        return y[:, :, 0, :].transpose(0, 2, 1)
    # Engine-exercising path: fold channels into batch (depthwise == C
    # independent single-channel convs).
    xt = x.transpose(0, 2, 1).reshape(b * c, 1, 1, l)
    xt = jnp.pad(xt, ((0, 0), (0, 0), (0, 0), (k - 1, 0)))
    wt = w.T.reshape(c, 1, 1, k)
    # vmap the engine over channels: each channel uses its own 1-tap filter.
    xg = xt.reshape(b, c, 1, 1, l + k - 1).transpose(1, 0, 2, 3, 4)
    def one(ch_x, ch_w):
        return conv2d(ch_x, ch_w[None], 1, (0, 0), mode)
    y = jax.vmap(one)(xg, wt)                               # (C, B, 1, 1, L)
    return y[:, :, 0, 0, :].transpose(1, 2, 0)


def output_shape(d: ConvDims) -> tuple[int, int, int, int]:
    return (d.B, d.N, d.H_o, d.W_o)
