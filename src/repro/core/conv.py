"""Convolution with a selectable backpropagation engine.

``conv2d(x, w, stride, padding, mode=...)`` computes the same forward result
for every mode; the mode chooses how the backward pass is realized:

  * ``"lax"``         -- XLA's native conv + autodiff (control / ground truth)
  * ``"traditional"`` -- explicit im2col with zero-space materialization (the
                         paper's baseline accelerator behaviour)
  * ``"bp_im2col"``   -- the paper's implicit algorithm: Algorithms 1 & 2
                         address mapping + gather (literal reproduction)
  * ``"bp_phase"``    -- TPU-native stride-phase decomposition (same zero
                         elimination, dense MXU form; the production path)
  * ``"pallas"``      -- Pallas kernels (phase-decomposed GEMMs with explicit
                         VMEM BlockSpecs; interpret=True on CPU)

``conv2d`` carries a ``jax.custom_vjp``: the forward runs the selected
engine and the backward dispatches the input gradient (transposed mode,
Algorithm 1 / phase decomposition) and the weight gradient (dilated mode,
Algorithm 2) through the same ``ENGINES`` registry, so ``jax.grad``, ``jit``
and ``vmap`` over any model transparently exercise the paper's datapath.
All static knobs (stride/padding/mode/groups) are nondiff arguments so jit
specializes per configuration; every mode is validated against ``jax.grad``
of the lax reference in tests/test_conv_modes.py.

Supported scenarios beyond the paper's square case:

  * asymmetric padding: ``padding=((top, bottom), (left, right))`` -- causal
    temporal convs are expressed as left-only pads;
  * grouped and depthwise conv via ``groups=`` (weights ``(N, C/g, Kh, Kw)``),
    lowered as a vmap of the selected engine over the group dim so the
    BP-im2col datapath is exercised per group;
  * ``conv1d`` / ``conv1d_causal`` / ``depthwise_causal_conv1d`` wrappers
    (used by the Mamba2 / RecurrentGemma temporal convolutions) which lower
    1-D convs onto the same engines as (H=1) 2-D convs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Literal

import jax
import jax.numpy as jnp

from repro.core import bpim2col, im2col_ref, phase_decomp
from repro.core.im2col_ref import ConvDims

Mode = Literal["lax", "traditional", "bp_im2col", "bp_phase", "pallas"]


def _norm_padding(padding) -> tuple[tuple[int, int], tuple[int, int]]:
    """int | (ph, pw) | ((ph_lo, ph_hi), (pw_lo, pw_hi)) -> nested tuples."""
    if isinstance(padding, int):
        return (padding, padding), (padding, padding)
    ph, pw = padding
    if isinstance(ph, int):
        ph = (ph, ph)
    if isinstance(pw, int):
        pw = (pw, pw)
    return (int(ph[0]), int(ph[1])), (int(pw[0]), int(pw[1]))


def make_dims(x_shape, w_shape, stride: int, padding,
              groups: int = 1) -> ConvDims:
    """Per-group ConvDims: C and N are the per-group channel counts."""
    b, c, h, w = x_shape
    n, cg, kh, kw = w_shape
    assert c == cg * groups, (
        f"channel mismatch: input C={c}, weight C/g={cg}, groups={groups}")
    assert n % groups == 0, f"N={n} not divisible by groups={groups}"
    (ph_lo, ph_hi), (pw_lo, pw_hi) = _norm_padding(padding)
    return ConvDims(B=b, C=cg, H_i=h, W_i=w, N=n // groups, K_h=kh, K_w=kw,
                    S=stride, P_h=ph_lo, P_w=pw_lo,
                    P_h_hi=ph_hi, P_w_hi=pw_hi)


# ---------------------------------------------------------------------------
# Mode registry: forward / input-grad / weight-grad per engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Engine:
    """The three lowered GEMMs of one conv layer under one engine."""
    forward: Callable      # (x, w, d) -> y
    input_grad: Callable   # (dy, w, d) -> dx   (transposed mode, Algorithm 1)
    weight_grad: Callable  # (x, dy, d) -> dw   (dilated mode, Algorithm 2)


def _pallas_forward(x, w, d):
    from repro.kernels import ops
    return ops.conv2d_forward(x, w, d)


def _pallas_input_grad(dy, w, d):
    from repro.kernels import ops
    return ops.conv2d_input_grad(dy, w, d)


def _pallas_weight_grad(x, dy, d):
    from repro.kernels import ops
    return ops.conv2d_weight_grad(x, dy, d)


def _lax_input_grad(dy, w, d):
    # Anchor: autodiff of the native conv (never dispatched through the
    # implicit path; used by mode="lax" and as the registry's control).
    x_shape = (d.B, d.C, d.H_i, d.W_i)
    _, vjp = jax.vjp(
        lambda x_: im2col_ref.conv2d_lax(x_, w, d),
        jnp.zeros(x_shape, dy.dtype))
    return vjp(dy)[0]


def _lax_weight_grad(x, dy, d):
    w_shape = (d.N, d.C, d.K_h, d.K_w)
    _, vjp = jax.vjp(
        lambda w_: im2col_ref.conv2d_lax(x, w_, d),
        jnp.zeros(w_shape, dy.dtype))
    return vjp(dy)[0]


ENGINES: dict[str, Engine] = {
    "lax": Engine(im2col_ref.conv2d_lax, _lax_input_grad, _lax_weight_grad),
    "traditional": Engine(im2col_ref.conv2d_forward_explicit,
                          im2col_ref.input_grad_explicit,
                          im2col_ref.weight_grad_explicit),
    "bp_im2col": Engine(im2col_ref.conv2d_forward_explicit,
                        bpim2col.input_grad_implicit,
                        bpim2col.weight_grad_implicit),
    "bp_phase": Engine(im2col_ref.conv2d_lax,
                       phase_decomp.input_grad_phase,
                       phase_decomp.weight_grad_phase),
    "pallas": Engine(_pallas_forward, _pallas_input_grad,
                     _pallas_weight_grad),
}

MODES: tuple[str, ...] = tuple(ENGINES)


def _engine(mode: Mode) -> Engine:
    try:
        return ENGINES[mode]
    except KeyError:
        raise ValueError(f"unknown conv mode {mode!r}; "
                         f"choose from {MODES}") from None


# ---------------------------------------------------------------------------
# Grouped dispatch: vmap the per-group engine over the group dim
# ---------------------------------------------------------------------------

def _split_groups(x, w, groups: int):
    """x (B,C,H,W), w (N,C/g,Kh,Kw) -> xg (g,B,C/g,H,W), wg (g,N/g,...)."""
    b, c, h, wd = x.shape
    n = w.shape[0]
    xg = x.reshape(b, groups, c // groups, h, wd).transpose(1, 0, 2, 3, 4)
    wg = w.reshape(groups, n // groups, *w.shape[1:])
    return xg, wg


def _merge_groups(yg):
    """(g, B, N/g, H, W) -> (B, g*N/g, H, W)."""
    g, b, ng, h, w = yg.shape
    return yg.transpose(1, 0, 2, 3, 4).reshape(b, g * ng, h, w)


def _forward(x, w, d: ConvDims, mode: Mode, groups: int):
    if groups == 1:
        return _engine(mode).forward(x, w, d)
    if mode == "lax":
        return jax.lax.conv_general_dilated(
            x, w, (d.S, d.S),
            [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=groups)
    xg, wg = _split_groups(x, w, groups)
    yg = jax.vmap(lambda xx, ww: _engine(mode).forward(xx, ww, d))(xg, wg)
    return _merge_groups(yg)


def _input_grad(dy, w, d: ConvDims, mode: Mode, groups: int):
    if groups == 1:
        return _engine(mode).input_grad(dy, w, d)
    b = dy.shape[0]
    dyg = dy.reshape(b, groups, d.N, d.H_o, d.W_o).transpose(1, 0, 2, 3, 4)
    wg = w.reshape(groups, d.N, *w.shape[1:])
    dxg = jax.vmap(lambda dd, ww: _engine(mode).input_grad(dd, ww, d))(dyg, wg)
    return _merge_groups(dxg)


def _weight_grad(x, dy, d: ConvDims, mode: Mode, groups: int):
    if groups == 1:
        return _engine(mode).weight_grad(x, dy, d)
    b, c = x.shape[0], x.shape[1]
    xg = x.reshape(b, groups, c // groups, d.H_i, d.W_i).transpose(
        1, 0, 2, 3, 4)
    dyg = dy.reshape(b, groups, d.N, d.H_o, d.W_o).transpose(1, 0, 2, 3, 4)
    dwg = jax.vmap(lambda xx, dd: _engine(mode).weight_grad(xx, dd, d))(
        xg, dyg)                                   # (g, N/g, C/g, Kh, Kw)
    return dwg.reshape(groups * d.N, d.C, d.K_h, d.K_w)


# ---------------------------------------------------------------------------
# custom_vjp conv
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def conv2d(x: jax.Array, w: jax.Array, stride: int = 1,
           padding=(0, 0), mode: Mode = "bp_phase",
           groups: int = 1) -> jax.Array:
    """NCHW x OIHW -> NCHW convolution with a selectable backprop engine.

    padding: int, (pad_h, pad_w), or ((top, bottom), (left, right)).
    groups:  feature groups; ``groups == C`` is depthwise.
    """
    d = _checked_dims(x.shape, w.shape, stride, padding, mode, groups)
    return _forward(x, w, d, mode, groups)


def _checked_dims(x_shape, w_shape, stride, padding, mode, groups):
    d = make_dims(x_shape, w_shape, stride, padding, groups)
    if mode != "lax":
        # The implicit engines assume the paper's geometry (P <= K-1 etc.);
        # fail at trace time with a clear message, not inside a deep pad op.
        d.validate()
    return d


def _conv2d_fwd(x, w, stride, padding, mode, groups):
    d = _checked_dims(x.shape, w.shape, stride, padding, mode, groups)
    return _forward(x, w, d, mode, groups), (x, w)


def _conv2d_bwd(stride, padding, mode, groups, res, dy):
    x, w = res
    d = make_dims(x.shape, w.shape, stride, padding, groups)
    dx = _input_grad(dy, w, d, mode, groups)
    dw = _weight_grad(x, dy, d, mode, groups)
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


# ---------------------------------------------------------------------------
# 1-D and depthwise wrappers (Mamba2 / RecurrentGemma temporal convs)
# ---------------------------------------------------------------------------

def conv1d(x: jax.Array, w: jax.Array, stride: int = 1, padding=0,
           mode: Mode = "bp_phase", groups: int = 1) -> jax.Array:
    """(B, C, L) x (N, C/g, K) -> (B, N, L_o) through the 2-D engines.

    padding: int (symmetric) or (lo, hi) along the temporal dim.
    """
    if isinstance(padding, int):
        padding = (padding, padding)
    x4 = x[:, :, None, :]
    w4 = w[:, :, None, :]
    y = conv2d(x4, w4, stride, ((0, 0), tuple(padding)), mode, groups)
    return y[:, :, 0, :]


def conv1d_causal(x: jax.Array, w: jax.Array, mode: Mode = "bp_phase",
                  groups: int = 1) -> jax.Array:
    """Causal (left-pad K-1) stride-1 conv1d: (B, C, L) -> (B, N, L)."""
    k = w.shape[-1]
    return conv1d(x, w, 1, (k - 1, 0), mode, groups)


def depthwise_causal_conv1d(x: jax.Array, w: jax.Array,
                            mode: Mode = "bp_phase") -> jax.Array:
    """Causal depthwise conv used by Mamba2: x (B, L, C), w (K, C).

    Lowered as a grouped (groups == C) causal conv1d: the causal shift is an
    asymmetric left-only pad and each channel convolves with its own K-tap
    filter, so the BP-im2col datapath is exercised for the depthwise case
    too.  The lax and bp_phase paths short-circuit to one fused
    conv_general_dilated with feature_group_count: a stride-1 backward has
    no zero-insertion, so the phase decomposition degenerates to exactly
    the native conv (same math, one XLA op on the production hot path).
    """
    b, l, c = x.shape
    k = w.shape[0]
    if mode in ("lax", "bp_phase"):
        xt = x.transpose(0, 2, 1)[:, :, None, :]            # (B, C, 1, L)
        wt = w.T[:, None, None, :]                          # (C, 1, 1, K)
        y = jax.lax.conv_general_dilated(
            xt, wt, (1, 1), [(0, 0), (k - 1, 0)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=c)
        return y[:, :, 0, :].transpose(0, 2, 1)
    xt = x.transpose(0, 2, 1)                           # (B, C, L)
    wt = w.T[:, None, :]                                # (C, 1, K)
    y = conv1d_causal(xt, wt, mode=mode, groups=c)      # (B, C, L)
    return y.transpose(0, 2, 1)


def output_shape(d: ConvDims) -> tuple[int, int, int, int]:
    return (d.B, d.N, d.H_o, d.W_o)


def conv_plan_report(x_shape, w_shape, stride: int = 1, padding=0,
                     groups: int = 1,
                     budget: int | None = None) -> dict[str, object]:
    """Static Pallas dispatch summary for one conv layer: per-op tile plans
    (spatial/channel tiles, split counts, VMEM footprint) and whether the
    whole layer stays on the Pallas path.  Convenience wrapper over
    ``repro.kernels.ops.plan_report`` taking array shapes instead of a
    ``ConvDims``; pure planner introspection, no arrays are touched."""
    from repro.kernels import ops
    d = make_dims(x_shape, w_shape, stride, padding, groups)
    return ops.plan_report(d, budget)
