"""Convolution with structured geometry and per-pass backprop engines.

The public surface is built from two objects (``repro.core.convspec``):

  * ``ConvSpec`` -- the layer geometry: per-axis stride, per-axis dilation,
    asymmetric padding, feature groups, activation layout;
  * ``EnginePolicy`` -- WHICH engine realizes each of the three lowered
    GEMMs (``forward`` / ``input_grad`` / ``weight_grad``), independently.

    y = conv2d(x, w, ConvSpec.make(stride=(2, 2), padding=1),
               EnginePolicy.parse("fwd=pallas,dgrad=auto,wgrad=bp_phase"))

Registered engines (``ENGINES``; extend with :func:`register_engine`):

  * ``"lax"``         -- XLA's native conv + autodiff (control / ground truth)
  * ``"traditional"`` -- explicit im2col with zero-space materialization (the
                         paper's baseline accelerator behaviour)
  * ``"bp_im2col"``   -- the paper's implicit algorithm: Algorithms 1 & 2
                         address mapping + gather (literal reproduction)
  * ``"bp_phase"``    -- stride-phase decomposition (same zero elimination,
                         dense MXU form; supports asymmetric strides)
  * ``"pallas"``      -- Pallas tap-GEMM kernels (explicit VMEM BlockSpecs;
                         per-axis tap tables, so asymmetric strides and
                         tap-native dilation are first-class)
  * ``"auto"``        -- not an engine: the resolver picks per pass.  It
                         consults the spec's geometry and the Pallas tile
                         planner (``repro.kernels.ops``): stride-1
                         undilated layers stay on the dense native path (no
                         zero-space to eliminate), strided OR dilated
                         layers take the Pallas tap-GEMM path whenever the
                         tile plan fits the VMEM budget, and every fallback
                         records WHY (:func:`policy_decisions`).

Engines that cannot serve a spec (asymmetric stride on an engine without
per-axis support -- declared via the ``asym_stride`` capability flag;
geometry outside the paper's ``P <= K - 1`` constraints on any implicit
engine; a tile plan over budget on ``pallas``) gracefully resolve to the
strongest capable engine -- the substitution is recorded, never silent:
:func:`dispatch_events` counts the engine *actually used* per pass and
:func:`policy_decisions` keeps the per-decision reasons.

The same ladder also runs at EXECUTION time (:func:`_execute`): an engine
that *raises* mid-pass is re-dispatched down the capability chain instead
of killing the step.  The failure edge is recorded
(``"pass:failed->survivor"`` in :func:`dispatch_events`, exception class in
:func:`runtime_failures`), the failing engine is quarantined for that
(pass, geometry) and probed for recovery after
:data:`QUARANTINE_PROBE_AFTER` dispatches, and a crashing pallas launch
poison-marks its plan-cache entry (``autotune.poison_plan``) so
``autotune="cached"`` cannot re-crash on restart.  ``lax`` is the terminal
anchor: never quarantined, and if every engine fails the first exception
propagates.

Dilation is lowered per engine, declared by the ``native_dilation``
capability flag.  Engines WITHOUT it get a dispatch-level kernel
materialization: the kernel is zero-dilated to its effective extent
(``K_eff = (K-1)*D + 1``) before entering the engine, and the weight
gradient's real taps are sliced back out -- exact, because the inserted
kernel zeros contribute nothing to ``y``/``dI`` and their ``dW`` entries
are discarded.  Engines WITH it (``pallas``) receive the compact kernel
untouched: their tap tables simply skip the zero positions, so a dilated
conv runs ``k_h*k_w`` tap-GEMMs instead of ``K_eff_h*K_eff_w`` --
~``1/(d_h*d_w)`` of the materialized FLOPs -- and the weight gradient is
computed only for real taps.  The materialization path stays registered as
the cross-check oracle the tests compare against.

``conv2d`` carries a ``jax.custom_vjp`` whose nondiff arguments are the
``(ConvSpec, EnginePolicy)`` pair, so ``jax.grad``, ``jit`` and ``vmap``
over any model transparently exercise a *mixed* datapath -- e.g. native
forward, Pallas input gradient, phase-decomposed weight gradient in one
training step.  :func:`conv_policy` is a context-manager override that
swaps the policy for every conv in scope (it beats per-call policies)
without rebuilding the model; it applies at trace time, so wrap the
``jit``/``grad`` call, not the cached executable.

Backward compatibility: the pre-ConvSpec surface
``conv2d(x, w, stride:int, padding, mode="bp_phase", groups)`` still works.
``mode=`` (kwarg or legacy 5th positional) maps to
``EnginePolicy.uniform(mode)`` and emits a ``DeprecationWarning``; loose
``stride=/padding=/dilation=/groups=`` kwargs are non-deprecated sugar that
builds the ``ConvSpec`` internally.  Passing a bare engine name as
``policy=`` is the blessed spelling of a uniform policy.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import bpim2col, im2col_ref, phase_decomp
from repro.obs import events as obs_events
from repro.obs import trace as obs_trace
from repro.core.convspec import (AUTO, ConvSpec, ConvTransposeSpec,
                                 EnginePolicy)
from repro.core.im2col_ref import ConvDims, rot180, zero_insert

Mode = str   # legacy alias: engine names are plain strings now


# ---------------------------------------------------------------------------
# Engine registry: forward / input-grad / weight-grad + capabilities
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Engine:
    """The three lowered GEMMs of one conv layer under one engine, plus the
    static capabilities the policy resolver gates on."""
    name: str
    forward: Callable      # (x, w, d) -> y
    input_grad: Callable   # (dy, w, d) -> dx   (transposed mode, Algorithm 1)
    weight_grad: Callable  # (x, dy, d) -> dw   (dilated mode, Algorithm 2)
    asym_stride: bool = False     # supports d.s_h != d.s_w
    paper_geometry: bool = True   # requires ConvDims.validate() (P <= K-1 ..)
    native_dilation: bool = False  # consumes the compact kernel and skips
    #                                dilation zero taps itself; False means
    #                                the dispatcher materializes the dilated
    #                                kernel before/after the engine runs
    native_transpose: bool = False  # serves a TRANSPOSED-conv forward
    #                                 implicitly (role-swapped onto its
    #                                 input_grad machinery, zero insertion
    #                                 never built); False means the
    #                                 dispatcher physically zero-inserts the
    #                                 input and runs the engine's ordinary
    #                                 stride-1 forward -- the materialization
    #                                 lowering that doubles as the oracle


def _pallas_forward(x, w, d):
    from repro.kernels import ops
    return ops.conv2d_forward(x, w, d)


def _pallas_input_grad(dy, w, d):
    from repro.kernels import ops
    return ops.conv2d_input_grad(dy, w, d)


def _pallas_weight_grad(x, dy, d):
    from repro.kernels import ops
    return ops.conv2d_weight_grad(x, dy, d)


def _lax_input_grad(dy, w, d):
    # Anchor: autodiff of the native conv (never dispatched through the
    # implicit path; used by engine "lax" and as the registry's control).
    x_shape = (d.B, d.C, d.H_i, d.W_i)
    _, vjp = jax.vjp(
        lambda x_: im2col_ref.conv2d_lax(x_, w, d),
        jnp.zeros(x_shape, dy.dtype))
    return vjp(dy)[0]


def _lax_weight_grad(x, dy, d):
    w_shape = (d.N, d.C, d.K_h, d.K_w)
    _, vjp = jax.vjp(
        lambda w_: im2col_ref.conv2d_lax(x, w_, d),
        jnp.zeros(w_shape, dy.dtype))
    return vjp(dy)[0]


ENGINES: dict[str, Engine] = {}


def register_engine(name: str, forward: Callable, input_grad: Callable,
                    weight_grad: Callable, *, asym_stride: bool = False,
                    paper_geometry: bool = True,
                    native_dilation: bool = False,
                    native_transpose: bool = False,
                    overwrite: bool = False) -> Engine:
    """Register a conv engine under ``name`` for use in any ``EnginePolicy``.

    The three callables take ``(x, w, d)`` / ``(dy, w, d)`` / ``(x, dy, d)``
    with ``d`` the per-group :class:`ConvDims`.  ``asym_stride`` declares
    support for ``d.s_h != d.s_w``; ``paper_geometry`` declares that the
    engine needs ``ConvDims.validate()`` to hold (the resolver falls back
    otherwise); ``native_dilation`` declares that the engine consumes the
    COMPACT kernel and handles ``d.D_h``/``d.D_w`` itself (skipping zero
    taps) -- without it, the dispatcher hands the engine a materialized
    zero-dilated kernel of extent ``K_eff`` and slices the real taps back
    out of its weight gradient.  ``native_transpose`` declares that the
    engine's ``input_grad`` implements the paper's transposed mode WITHOUT
    building the zero-spaced tensor, so a transposed-conv *forward* may be
    role-swapped onto it (and its ``forward``/``weight_grad`` serve the
    transposed layer's dX/dW, which are ordinary regular-conv passes) --
    without it, the dispatcher physically zero-inserts the input and runs
    the engine's ordinary stride-1 forward (the materialization lowering,
    kept as the cross-check oracle).  Re-registering an existing name
    requires ``overwrite=True``.
    """
    if name == AUTO or not name:
        raise ValueError(f"invalid engine name {name!r}")
    if name in ENGINES and not overwrite:
        raise ValueError(f"engine {name!r} is already registered "
                         "(pass overwrite=True to replace it)")
    eng = Engine(name, forward, input_grad, weight_grad,
                 asym_stride=asym_stride, paper_geometry=paper_geometry,
                 native_dilation=native_dilation,
                 native_transpose=native_transpose)
    ENGINES[name] = eng
    return eng


register_engine("lax", im2col_ref.conv2d_lax, _lax_input_grad,
                _lax_weight_grad, asym_stride=True, paper_geometry=False,
                native_transpose=True)
register_engine("traditional", im2col_ref.conv2d_forward_explicit,
                im2col_ref.input_grad_explicit,
                im2col_ref.weight_grad_explicit, asym_stride=True)
register_engine("bp_im2col", im2col_ref.conv2d_forward_explicit,
                bpim2col.input_grad_implicit,
                bpim2col.weight_grad_implicit, asym_stride=True,
                native_transpose=True)
register_engine("bp_phase", im2col_ref.conv2d_lax,
                phase_decomp.input_grad_phase,
                phase_decomp.weight_grad_phase, asym_stride=True,
                native_transpose=True)
register_engine("pallas", _pallas_forward, _pallas_input_grad,
                _pallas_weight_grad, asym_stride=True,
                native_dilation=True, native_transpose=True)

#: the built-in engine names (legacy export; registry may grow beyond it).
MODES: tuple[str, ...] = tuple(ENGINES)


def _engine(name: str) -> Engine:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown conv engine {name!r}; choose from "
            f"{tuple(ENGINES)} or 'auto'") from None


# ---------------------------------------------------------------------------
# Geometry: ConvSpec + shapes -> per-group ConvDims (dilation folded in)
# ---------------------------------------------------------------------------

def make_dims(x_shape, w_shape, stride=1, padding=0,
              groups: int = 1, dilation=1) -> ConvDims:
    """Per-group ConvDims: C and N are the per-group channel counts.

    ``stride``/``dilation`` accept an int or a per-axis pair.  Dilation is
    folded into the kernel extent (``K_h``/``K_w`` are the EFFECTIVE
    ``K_eff``) and also recorded per axis (``D_h``/``D_w``), so
    materializing engines and the tap-native Pallas engine both read the
    geometry they need from the same dims.
    """
    return spec_dims(x_shape, w_shape,
                     ConvSpec.make(stride=stride, padding=padding,
                                   dilation=dilation, groups=groups))


def spec_dims(x_shape, w_shape, spec: ConvSpec) -> ConvDims:
    """The per-group ``ConvDims`` a ``(x, w, spec)`` triple dispatches with."""
    b, c, h, w = x_shape
    n, cg, kh, kw = w_shape
    g = spec.groups
    assert c == cg * g, (
        f"channel mismatch: input C={c}, weight C/g={cg}, groups={g}")
    assert n % g == 0, f"N={n} not divisible by groups={g}"
    keff_h, keff_w = spec.effective_kernel(kh, kw)
    (ph_lo, ph_hi), (pw_lo, pw_hi) = spec.padding
    d = ConvDims(B=b, C=cg, H_i=h, W_i=w, N=n // g,
                 K_h=keff_h, K_w=keff_w,
                 S=spec.s_h, S_w=(-1 if spec.s_w == spec.s_h else spec.s_w),
                 P_h=ph_lo, P_w=pw_lo, P_h_hi=ph_hi, P_w_hi=pw_hi,
                 D_h=spec.d_h, D_w=spec.d_w)
    if d.H_o < 1 or d.W_o < 1:
        # A mis-sized layer, not a capability question: fail at trace time
        # for EVERY engine rather than training on empty activations.
        raise ValueError(
            f"conv output plane is empty ({d.H_o}x{d.W_o}): input "
            f"{h}x{w}, effective kernel {keff_h}x{keff_w} "
            f"(dilation {spec.dilation}), stride {spec.stride}, "
            f"padding {spec.padding}")
    return d


def transpose_dims(x_shape, w_shape, spec: ConvTransposeSpec) -> ConvDims:
    """Per-group ``ConvDims`` of the MIRROR regular conv of a transposed
    layer.

    A transposed conv with forward stride ``s`` *is* the input gradient
    (the paper's transposed mode) of a regular conv whose output plane is
    the transposed layer's input: its ``ConvDims`` carry the transposed
    spec's stride/dilation/padding verbatim, its input plane is the
    transposed layer's OUTPUT, and ``output_padding`` lands exactly on the
    tiling remainder ``R`` (the extra high-side rows/cols the mirror
    conv's last stride window does not reach -- already first-class since
    the engines support general ``R``).  Every engine pass of the
    transposed layer is then a role-swap of the mirror conv's passes:

        forward      -> mirror input_grad   (Algorithm 1 / tap-GEMM phases)
        input grad   -> mirror forward      (an ordinary strided conv)
        weight grad  -> mirror weight_grad  (Algorithm 2, roles swapped)

    Weights ``(C_in, C_out/g, K_h, K_w)`` are the mirror conv's OIHW
    weights unchanged (``N = C_in/g`` per group, ``C = C_out/g``).
    """
    b, cin, h, w = x_shape
    cin2, cog, kh, kw = w_shape
    g = spec.groups
    assert cin == cin2, (
        f"channel mismatch: input C={cin}, weight C_in={cin2}")
    assert cin % g == 0, f"C_in={cin} not divisible by groups={g}"
    keff_h, keff_w = spec.effective_kernel(kh, kw)
    (ph_lo, ph_hi), (pw_lo, pw_hi) = spec.padding
    h_out, w_out = spec.output_shape(h, w, kh, kw)
    if h_out < 1 or w_out < 1:
        raise ValueError(
            f"transposed-conv output plane is empty ({h_out}x{w_out}): "
            f"input {h}x{w}, effective kernel {keff_h}x{keff_w} "
            f"(dilation {spec.dilation}), stride {spec.stride}, "
            f"padding {spec.padding}, output_padding {spec.output_padding}")
    d = ConvDims(B=b, C=cog, H_i=h_out, W_i=w_out, N=cin // g,
                 K_h=keff_h, K_w=keff_w,
                 S=spec.s_h, S_w=(-1 if spec.s_w == spec.s_h else spec.s_w),
                 P_h=ph_lo, P_w=pw_lo, P_h_hi=ph_hi, P_w_hi=pw_hi,
                 D_h=spec.d_h, D_w=spec.d_w)
    # The mirror conv must reproduce the transposed layer's input plane
    # exactly, with output_padding as the remainder (guaranteed by the
    # spec's 0 <= output_padding < stride validation).
    assert d.H_o == h and d.W_o == w, (d, x_shape, spec)
    assert d.R_h == spec.op_h and d.R_w == spec.op_w, (d, spec)
    return d


def conv_transpose_output_shape(x_shape, w_shape,
                                spec: ConvTransposeSpec) \
        -> tuple[int, int, int, int]:
    """The exact output shape of ``conv2d_transpose`` in the spec's layout:
    (B, C_out, H_out, W_out) for NCHW, (B, H_out, W_out, C_out) for NHWC."""
    b = x_shape[0]
    cout = w_shape[1] * spec.groups
    h, w = (x_shape[2], x_shape[3]) if spec.layout == "NCHW" \
        else (x_shape[1], x_shape[2])
    h_out, w_out = spec.output_shape(h, w, w_shape[2], w_shape[3])
    if spec.layout == "NHWC":
        return b, h_out, w_out, cout
    return b, cout, h_out, w_out


def transpose_tap_counts(d: ConvDims) -> dict[str, object]:
    """The zero-insertion accounting of one transposed-conv forward.

    ``real`` is the number of tap-GEMMs the fused phase plan actually runs
    across all ``s_h*s_w`` output phases (every real kernel tap belongs to
    exactly one phase, so full coverage totals ``k_taps_h * k_taps_w``);
    ``zero_inserted`` is what a stride-1 dense conv over the physically
    zero-inserted input would run over the same phase grid
    (``s_h*s_w*K_eff_h*K_eff_w``).  ``skip_ratio`` is therefore
    ``1 - 1/(s_h*s_w)`` for a dense kernel, and folds in the additional
    ``1/(d_h*d_w)`` kernel-dilation skipping."""
    from repro.kernels import ops
    pp = ops.input_grad_plan(d)
    if pp is not None:
        real = sum(len(t) for t in pp.phase_taps)
    else:   # jnp phase-decomposition fallback: per-phase subsamples of the
            # zero-dilated kernel (every effective position in one phase)
        real = d.K_h * d.K_w
    zero_inserted = d.s_h * d.s_w * d.K_h * d.K_w
    return {"real": real, "zero_inserted": zero_inserted,
            "skip_ratio": round(1.0 - real / zero_inserted, 3)}


def _dilate_weight(w: jax.Array, spec: ConvSpec) -> jax.Array:
    """Materialize the dilated kernel (zeros between taps) so an engine
    WITHOUT native dilation sees an ordinary dense conv of extent K_eff."""
    if not spec.has_dilation:
        return w
    return zero_insert(w, (spec.d_h, spec.d_w))


def _undilate_dweight(dw_eff: jax.Array, spec: ConvSpec) -> jax.Array:
    """Slice the real taps back out of the effective-kernel weight grad."""
    if not spec.has_dilation:
        return dw_eff
    return dw_eff[..., ::spec.d_h, ::spec.d_w]


def _weight_for(eng: Engine, w: jax.Array, spec: ConvSpec) -> jax.Array:
    """The kernel an engine consumes: compact for native-dilation engines
    (their tap tables skip the zero positions), materialized otherwise."""
    return w if eng.native_dilation else _dilate_weight(w, spec)


# ---------------------------------------------------------------------------
# Policy resolution: requested engine -> engine actually dispatched
# ---------------------------------------------------------------------------

#: (pass, engine-actually-used) trace-time counters, key "pass:engine".
DISPATCH_EVENTS: dict[str, int] = {}

#: mesh-parallel lowering hook, installed by
#: ``repro.dist.conv_parallel.conv_mesh``.  Called as ``hook(x, w, spec,
#: policy)`` with the NCHW-normalized spec (ConvSpec or ConvTransposeSpec);
#: returns the sharded result or ``NotImplemented`` to decline, in which
#: case the single-device custom_vjp proceeds unchanged.  A mesh-aware
#: RESOLUTION step, not an engine: inside the sharded lowering every local
#: pass still dispatches through ``resolve_engine``/``_execute``.
MESH_LOWERING = None


def _mesh_dispatch(fn, x, w, spec, policy):
    """Offer one conv call to the mesh hook before the single-device vjp."""
    hook = MESH_LOWERING
    if hook is not None:
        out = hook(x, w, spec, policy)
        if out is not NotImplemented:
            return out
    return fn(x, w, spec, policy)

#: per-decision log: requested engine, engine used, and why (bounded).
POLICY_DECISIONS: list[dict] = []
_MAX_DECISIONS = 512


def dispatch_events() -> dict[str, int]:
    """Counts of the engine ACTUALLY used per pass (``"input_grad:pallas"``
    -> n), recorded at trace time inside the custom_vjp.  A jit cache hit
    does not re-trace and therefore does not re-count."""
    return dict(DISPATCH_EVENTS)


def policy_decisions() -> list[dict]:
    return list(POLICY_DECISIONS)


def reset_dispatch_events() -> None:
    DISPATCH_EVENTS.clear()
    POLICY_DECISIONS.clear()
    RUNTIME_FAILURES.clear()
    _QUARANTINE.clear()
    # Keep the bus-backed view (obs.events.counters("dispatch")) in lockstep
    # with the legacy dict under every reset pattern (no-op when off).
    obs_events.drop("dispatch")


def _paper_geometry_gap(d: ConvDims) -> str | None:
    """The ``ConvDims.validate()`` conditions, evaluated explicitly: the
    resolver ROUTES on this (not just error messaging), so it must not
    evaporate under ``python -O`` the way a bare assert would."""
    if d.H_o < 1 or d.W_o < 1:
        return f"empty output plane ({d.H_o}x{d.W_o})"
    if d.K_h - 1 - d.P_h < 0 or d.K_w - 1 - d.P_w < 0:
        return "transposed-conv padding K-1-P is negative"
    if d.K_h - 1 - d.p_h_hi + d.R_h < 0 or d.K_w - 1 - d.p_w_hi + d.R_w < 0:
        return "high-side transposed-conv padding K-1-P_hi+R is negative"
    return None


def _capability_gap(e: Engine, d: ConvDims) -> str | None:
    """None when ``e`` can serve geometry ``d``, else the human reason."""
    if d.s_h != d.s_w and not e.asym_stride:
        return (f"asymmetric stride ({d.s_h}, {d.s_w}) needs per-axis phase "
                "support")
    if e.paper_geometry:
        gap = _paper_geometry_gap(d)
        if gap is not None:
            return f"geometry outside the paper's constraints ({gap})"
    return None


#: transposed-conv pass -> the MIRROR regular-conv pass it role-swaps onto.
_TRANSPOSE_ROLE = {"forward": "input_grad", "input_grad": "forward",
                   "weight_grad": "weight_grad"}


def _pallas_fits(pass_name: str, d: ConvDims,
                 transposed: bool = False) -> bool:
    from repro.kernels import ops
    if transposed:
        pass_name = _TRANSPOSE_ROLE[pass_name]
    if pass_name == "forward":
        return ops.forward_plan(d).fits
    if pass_name == "input_grad":
        return ops.input_grad_plan(d) is not None
    return ops.weight_grad_plan(d).fits


_FALLBACK_CHAIN = ("bp_phase", "lax")


def _first_capable(d: ConvDims, reason: str) -> tuple[str, str]:
    for name in _FALLBACK_CHAIN:
        if name in ENGINES and _capability_gap(ENGINES[name], d) is None:
            return name, reason
    return "lax", reason


def resolve_engine(requested: str, pass_name: str, d: ConvDims,
                   transposed: bool = False) -> tuple[str, str]:
    """One pass's selection: ``(engine actually used, reason)``.

    ``"auto"`` is the shape-dependent strategy: stride-1 undilated layers
    have no zero-space (the phase decomposition degenerates to the native
    dense conv, which is optimal), strided or dilated layers go to the
    Pallas tap-GEMM -- per-axis tap tables serve asymmetric strides, and a
    dilated kernel's zero taps are skipped rather than materialized --
    whenever the tile plan fits, and everything else falls back down
    ``bp_phase -> lax`` with the reason recorded.  Explicit requests that
    the engine cannot serve resolve the same way -- recorded, not silent.

    ``transposed=True`` resolves the pass of a TRANSPOSED conv over the
    mirror dims ``d`` (see :func:`transpose_dims`): the tile planner
    consulted is the role-swapped one (the transposed forward runs the
    mirror input-grad phase plan), and ``"auto"`` keeps plannable
    transposed specs on ``pallas`` -- the stride IS the zero-insertion.
    """
    if requested == AUTO:
        if d.s_h == 1 and d.s_w == 1 and not d.has_dilation:
            if _capability_gap(ENGINES["bp_phase"], d) is None:
                return "bp_phase", ("auto: stride 1 has no zero-space; "
                                    "phase decomposition degenerates to the "
                                    "native dense conv")
            return _first_capable(
                d, "auto: stride 1, geometry outside implicit constraints")
        gap = _capability_gap(ENGINES["pallas"], d)
        if gap is None and _pallas_fits(pass_name, d, transposed):
            if transposed:
                return "pallas", ("auto: transposed conv is the tap-GEMM "
                                  "phase plan; zero insertion skipped at "
                                  "plan time and the tile plan fits the "
                                  "VMEM budget")
            if d.has_dilation:
                return "pallas", ("auto: tap table skips the dilation zero "
                                  "taps and the tile plan fits the VMEM "
                                  "budget")
            return "pallas", "auto: tap-GEMM tile plan fits the VMEM budget"
        return _first_capable(
            d, f"auto: pallas unavailable "
               f"({gap or 'tile plan exceeds the VMEM budget'})")
    e = _engine(requested)
    gap = _capability_gap(e, d)
    if gap is not None:
        return _first_capable(d, f"{requested} requested but {gap}")
    if requested == "pallas" and not _pallas_fits(pass_name, d, transposed):
        return _first_capable(
            d, "pallas requested but the tile plan exceeds the VMEM budget")
    return requested, "requested"


# ---------------------------------------------------------------------------
# Runtime graceful degradation: execute-with-fallback, quarantine, probes
# ---------------------------------------------------------------------------

#: structured log of runtime engine failures (bounded like the decisions).
RUNTIME_FAILURES: list[dict] = []

#: a quarantined (pass, engine, geometry) is skipped for this many
#: dispatches, then probed for recovery (each dispatch is one trace -- one
#: step when the caller is eager, one retrace boundary under jit).
QUARANTINE_PROBE_AFTER = 3

#: (pass_key, engine, d) -> dispatches skipped since quarantine began.
_QUARANTINE: dict[tuple, int] = {}


def runtime_failures() -> list[dict]:
    """Every runtime engine failure absorbed by the degradation layer:
    pass, engine, exception class, the survivor that served the pass, and
    the geometry.  Reset by :func:`reset_dispatch_events`."""
    return list(RUNTIME_FAILURES)


def quarantined_engines() -> list[dict]:
    """The currently quarantined (pass, engine, geometry) entries and how
    many dispatches each has been skipped for."""
    return [{"pass": k[0], "engine": k[1], "dims": k[2], "skips": v}
            for k, v in sorted(_QUARANTINE.items(),
                               key=lambda kv: (kv[0][0], kv[0][1]))]


def clear_quarantine() -> None:
    _QUARANTINE.clear()


def _record_event(key: str) -> None:
    DISPATCH_EVENTS[key] = DISPATCH_EVENTS.get(key, 0) + 1
    obs_events.emit("dispatch", key)


def _dims_key(d: ConvDims) -> tuple:
    return (d.B, d.C, d.H_i, d.W_i, d.N, d.K_h, d.K_w, d.s_h, d.s_w)


def _runtime_chain(name: str, d: ConvDims) -> list[str]:
    """``name`` followed by the capability-ordered engines below it --
    the same ``bp_phase -> lax`` ladder plan-time fallback walks, with
    ``lax`` always terminal."""
    chain = [name]
    for cand in _FALLBACK_CHAIN:
        if cand != name and cand in ENGINES and \
                _capability_gap(ENGINES[cand], d) is None:
            chain.append(cand)
    if "lax" not in chain:
        chain.append("lax")
    return chain


def _poison_plan_entry(pass_name: str, transposed: bool, d: ConvDims) -> None:
    """Poison-mark the plan-cache entry that fed a crashing pallas launch
    (best effort -- poisoning must never mask the degradation itself)."""
    from repro.core.config import config
    if config.autotune == "off":
        return
    role = _TRANSPOSE_ROLE[pass_name] if transposed else pass_name
    try:
        from repro.kernels import autotune
        autotune.poison_plan(role, d)
    except Exception:
        pass


def _execute(pass_name: str, requested: str, d: ConvDims, transposed: bool,
             run: Callable):
    """Resolve one conv pass and execute it with runtime degradation.

    ``run(engine)`` performs the pass.  An exception from the engine
    re-dispatches down the capability-ordered fallback chain: the failure
    is recorded (``dispatch_events`` gains ``"pass:failed->survivor"``,
    :func:`runtime_failures` keeps the exception class), the failing
    engine is QUARANTINED for this (pass, geometry) -- subsequent
    dispatches skip it for :data:`QUARANTINE_PROBE_AFTER` rounds, then
    probe it once; a successful probe lifts the quarantine
    (``"pass:engine:recovered"``), a failed one re-arms it -- and a
    crashing pallas launch poison-marks its plan-cache entry so
    ``autotune="cached"`` cannot re-crash on restart.  ``lax`` is the
    terminal anchor: it is never quarantined, and when every engine in
    the chain fails the FIRST exception propagates (nothing to degrade
    to).  The no-failure path records exactly what it always did: one
    dispatch event, one policy decision.
    """
    name, reason = resolve_engine(requested, pass_name, d, transposed)
    # Transposed-conv passes count under their own keys ("forward_T:pallas")
    # so a decoder's dispatch is distinguishable from its encoder's.
    pkey = f"{pass_name}{'_T' if transposed else ''}"
    first_exc = None
    failures: list[dict] = []
    for cand in _runtime_chain(name, d):
        qkey = (pkey, cand, _dims_key(d))
        probing = False
        if qkey in _QUARANTINE and cand != "lax":
            _QUARANTINE[qkey] += 1
            if _QUARANTINE[qkey] <= QUARANTINE_PROBE_AFTER:
                _record_event(f"{pkey}:{cand}:quarantined")
                continue
            probing = True
            _record_event(f"{pkey}:{cand}:probe")
        try:
            with obs_trace.dispatch_span(pkey, cand, d):
                out = run(ENGINES[cand])
        except Exception as e:
            if first_exc is None:
                first_exc = e
            if cand != "lax":
                _QUARANTINE[qkey] = 0
            fail = {"pass": pkey, "engine": cand,
                    "exception": type(e).__name__, "error": str(e)[:200],
                    "survivor": None, "probe": probing,
                    "dims": _dims_key(d)}
            failures.append(fail)
            if len(RUNTIME_FAILURES) < _MAX_DECISIONS:
                RUNTIME_FAILURES.append(fail)
            if cand == "pallas":
                _poison_plan_entry(pass_name, transposed, d)
            continue
        if probing:
            del _QUARANTINE[qkey]
            _record_event(f"{pkey}:{cand}:recovered")
        for fail in failures:
            fail["survivor"] = cand
            _record_event(f"{pkey}:{fail['engine']}->{cand}")
            reason = (f"runtime degradation: {fail['engine']} raised "
                      f"{fail['exception']}; quarantined, {cand} survives")
        _record_event(f"{pkey}:{cand}")
        if len(POLICY_DECISIONS) < _MAX_DECISIONS:
            POLICY_DECISIONS.append({
                "pass": pass_name, "requested": requested, "engine": cand,
                "reason": reason, "transpose": transposed,
                "dims": _dims_key(d)})
        return out
    if first_exc is not None:
        raise first_exc
    raise RuntimeError(
        f"every engine for {pkey} is quarantined for dims {_dims_key(d)}; "
        f"chain {_runtime_chain(name, d)}")


def _validate_policy(policy: EnginePolicy) -> EnginePolicy:
    for _, engine in policy.slots():
        if engine != AUTO:
            _engine(engine)           # raises on unknown names
    return policy


# ---------------------------------------------------------------------------
# Grouped dispatch: vmap the per-group engine over the group dim
# ---------------------------------------------------------------------------

def _split_groups(x, w, groups: int):
    """x (B,C,H,W), w (N,C/g,Kh,Kw) -> xg (g,B,C/g,H,W), wg (g,N/g,...)."""
    b, c, h, wd = x.shape
    n = w.shape[0]
    xg = x.reshape(b, groups, c // groups, h, wd).transpose(1, 0, 2, 3, 4)
    wg = w.reshape(groups, n // groups, *w.shape[1:])
    return xg, wg


def _merge_groups(yg):
    """(g, B, N/g, H, W) -> (B, g*N/g, H, W)."""
    g, b, ng, h, w = yg.shape
    return yg.transpose(1, 0, 2, 3, 4).reshape(b, g * ng, h, w)


def _forward(x, w, d: ConvDims, eng: Engine, groups: int):
    if groups == 1:
        return eng.forward(x, w, d)
    if eng.name == "lax":
        return jax.lax.conv_general_dilated(
            x, w, (d.s_h, d.s_w),
            [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=groups)
    xg, wg = _split_groups(x, w, groups)
    yg = jax.vmap(lambda xx, ww: eng.forward(xx, ww, d))(xg, wg)
    return _merge_groups(yg)


def _input_grad(dy, w, d: ConvDims, eng: Engine, groups: int):
    if groups == 1:
        return eng.input_grad(dy, w, d)
    b = dy.shape[0]
    dyg = dy.reshape(b, groups, d.N, d.H_o, d.W_o).transpose(1, 0, 2, 3, 4)
    wg = w.reshape(groups, d.N, *w.shape[1:])
    dxg = jax.vmap(lambda dd, ww: eng.input_grad(dd, ww, d))(dyg, wg)
    return _merge_groups(dxg)


def _weight_grad(x, dy, d: ConvDims, eng: Engine, groups: int):
    if groups == 1:
        return eng.weight_grad(x, dy, d)
    b, c = x.shape[0], x.shape[1]
    xg = x.reshape(b, groups, c // groups, d.H_i, d.W_i).transpose(
        1, 0, 2, 3, 4)
    dyg = dy.reshape(b, groups, d.N, d.H_o, d.W_o).transpose(1, 0, 2, 3, 4)
    dwg = jax.vmap(lambda xx, dd: eng.weight_grad(xx, dd, d))(
        xg, dyg)                                   # (g, N/g, C/g, kh, kw)
    # Kernel extent from the engine's output: compact (k_taps) for
    # native-dilation engines, effective (K_eff) otherwise.
    return dwg.reshape(groups * d.N, *dwg.shape[2:])


# ---------------------------------------------------------------------------
# Policy override context and the default policy
# ---------------------------------------------------------------------------

#: the repo-wide default: shape-dependent per-pass selection.
DEFAULT_POLICY = EnginePolicy()

_POLICY_OVERRIDE: list[EnginePolicy] = []


@contextlib.contextmanager
def conv_policy(policy):
    """Scoped policy override for EVERY conv2d/conv1d in the dynamic extent.

    Beats per-call and per-config policies, so an experiment can swap
    engines without rebuilding the model::

        with conv_policy("fwd=lax,dgrad=pallas,wgrad=bp_phase"):
            grads = jax.grad(loss)(params)      # traced under the override

    Applies at TRACE time (the policy is a static jit argument): wrap the
    call that traces, not an already-compiled executable.
    """
    p = EnginePolicy.coerce(policy)
    _validate_policy(p)
    _POLICY_OVERRIDE.append(p)
    try:
        yield p
    finally:
        _POLICY_OVERRIDE.pop()


def effective_policy(explicit=None) -> EnginePolicy:
    """Override stack > per-call/explicit policy > DEFAULT_POLICY (auto)."""
    if _POLICY_OVERRIDE:
        return _POLICY_OVERRIDE[-1]
    if explicit is not None:
        return EnginePolicy.coerce(explicit)
    return DEFAULT_POLICY


# ---------------------------------------------------------------------------
# custom_vjp conv on the structured surface
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _conv2d(x: jax.Array, w: jax.Array, spec: ConvSpec,
            policy: EnginePolicy) -> jax.Array:
    d = spec_dims(x.shape, w.shape, spec)
    return _execute(
        "forward", policy.forward, d, False,
        lambda eng: _forward(x, _weight_for(eng, w, spec), d, eng,
                             spec.groups))


def _conv2d_fwd(x, w, spec, policy):
    d = spec_dims(x.shape, w.shape, spec)
    y = _execute(
        "forward", policy.forward, d, False,
        lambda eng: _forward(x, _weight_for(eng, w, spec), d, eng,
                             spec.groups))
    return y, (x, w)


def _run_wgrad(x, dy, d, eng, spec):
    """One engine's complete weight-grad pass, un-dilation included --
    the degradation unit must cover the whole engine-dependent pipeline,
    since the survivor may differ in ``native_dilation``."""
    dw = _weight_grad(x, dy, d, eng, spec.groups)
    if not eng.native_dilation:
        dw = _undilate_dweight(dw, spec)
    return dw


def _conv2d_bwd(spec, policy, res, dy):
    x, w = res
    d = spec_dims(x.shape, w.shape, spec)
    dx = _execute(
        "input_grad", policy.input_grad, d, False,
        lambda eng: _input_grad(dy, _weight_for(eng, w, spec), d, eng,
                                spec.groups))
    dw = _execute(
        "weight_grad", policy.weight_grad, d, False,
        lambda eng: _run_wgrad(x, dy, d, eng, spec))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)


# ---------------------------------------------------------------------------
# Transposed convolution: tap-native lhs dilation through the same engines
# ---------------------------------------------------------------------------

def conv2d_transpose_materialized(x: jax.Array, w: jax.Array,
                                  spec: ConvTransposeSpec,
                                  engine: str = "lax") -> jax.Array:
    """The zero-insertion MATERIALIZATION of a transposed conv: physically
    build the lhs-dilated input (``s - 1`` zeros between pixels, virtual
    pad ``K_eff - 1 - p`` per side, ``output_padding`` extra rows/cols on
    the high side), rotate/swap the (zero-dilated) kernel, and run an
    ordinary stride-1 dense conv over the zero-spaced tensor.

    This is what engines WITHOUT the ``native_transpose`` capability get
    at dispatch, and it is the executable oracle the tap-native path is
    tested against -- it pays exactly the reorganization + zero-FLOPs the
    paper eliminates.  Differentiable (pure jax ops), so ``jax.grad`` of
    it anchors the transposed VJP too.
    """
    eng = _engine(engine)
    b, cin, h, wd = x.shape
    g = spec.groups
    cog = w.shape[1]
    w_eff = _dilate_weight(w, spec)          # (C_in, C_out/g, Keff, Keff)
    keff_h, keff_w = w_eff.shape[-2:]
    (ph_lo, ph_hi), (pw_lo, pw_hi) = spec.padding
    # lax.pad applies the interior (zero-insertion) dilation first, then
    # the edge pads -- negative edge pads crop, so p > K_eff - 1 works too.
    x_zi = jax.lax.pad(
        x, jnp.zeros((), x.dtype),
        [(0, 0, 0), (0, 0, 0),
         (keff_h - 1 - ph_lo, keff_h - 1 - ph_hi + spec.op_h, spec.s_h - 1),
         (keff_w - 1 - pw_lo, keff_w - 1 - pw_hi + spec.op_w, spec.s_w - 1)])
    # Mirror OIHW weight of the stride-1 dense conv: rot180 + in/out swap.
    wt = rot180(w_eff).reshape(g, cin // g, cog, keff_h, keff_w)
    wt = wt.transpose(0, 2, 1, 3, 4).reshape(g * cog, cin // g,
                                             keff_h, keff_w)
    d1 = ConvDims(B=b, C=cin // g, H_i=x_zi.shape[2], W_i=x_zi.shape[3],
                  N=cog, K_h=keff_h, K_w=keff_w, S=1)
    return _forward(x_zi, wt, d1, eng, g)


def _t_forward(x, w, d: ConvDims, eng: Engine, spec: ConvTransposeSpec):
    """Transposed forward under one engine: role-swap onto the mirror
    input-grad machinery when the engine is transpose-native (zero space
    never built), else the physical zero-insertion lowering."""
    if not eng.native_transpose:
        return conv2d_transpose_materialized(x, w, spec, eng.name)
    return _input_grad(x, _weight_for(eng, w, spec), d, eng, spec.groups)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _conv2d_transpose(x: jax.Array, w: jax.Array, spec: ConvTransposeSpec,
                      policy: EnginePolicy) -> jax.Array:
    d = transpose_dims(x.shape, w.shape, spec)
    return _execute("forward", policy.forward, d, True,
                    lambda eng: _t_forward(x, w, d, eng, spec))


def _conv2d_transpose_fwd(x, w, spec, policy):
    d = transpose_dims(x.shape, w.shape, spec)
    y = _execute("forward", policy.forward, d, True,
                 lambda eng: _t_forward(x, w, d, eng, spec))
    return y, (x, w)


def _conv2d_transpose_bwd(spec, policy, res, dy):
    x, w = res
    d = transpose_dims(x.shape, w.shape, spec)
    # dX of a transposed conv is the mirror STRIDED regular conv of dy;
    # dW is the mirror weight grad with the input/output roles swapped.
    dx = _execute(
        "input_grad", policy.input_grad, d, True,
        lambda eng: _forward(dy, _weight_for(eng, w, spec), d, eng,
                             spec.groups))
    dw = _execute(
        "weight_grad", policy.weight_grad, d, True,
        lambda eng: _run_wgrad(dy, x, d, eng, spec))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_conv2d_transpose.defvjp(_conv2d_transpose_fwd, _conv2d_transpose_bwd)


def _canon_transpose_call(args: tuple, kw: dict) \
        -> tuple[ConvTransposeSpec, EnginePolicy | None]:
    """conv2d_transpose(x, w, spec | policy, policy=..., <geometry kwargs>)
    -- the structured surface only (this API postdates ``mode=``)."""
    spec = kw.pop("spec", None)
    policy = kw.pop("policy", None)
    geom = {k: kw.pop(k) for k in ("stride", "padding", "output_padding",
                                   "dilation", "groups", "layout")
            if k in kw}
    if kw:
        raise TypeError(
            f"conv2d_transpose got unexpected kwargs {sorted(kw)}")
    args = list(args)
    if args and isinstance(args[0], ConvTransposeSpec):
        if spec is not None:
            raise TypeError(
                "ConvTransposeSpec given both positionally and as spec=")
        spec = args.pop(0)
    if args:
        if policy is not None:
            raise TypeError("policy given twice")
        if not isinstance(args[0], (str, EnginePolicy)):
            raise TypeError(
                "expected a policy (str | EnginePolicy) after the spec, "
                f"got {args[0]!r}")
        policy = args.pop(0)
    if args:
        raise TypeError("too many positional arguments")
    if spec is None:
        spec = ConvTransposeSpec.make(**geom)
    elif geom:
        raise TypeError(
            f"geometry given both in the ConvTransposeSpec and as kwargs "
            f"{sorted(geom)}; put it all in the spec")
    return spec, policy


def conv2d_transpose(x: jax.Array, w: jax.Array, *args, **kwargs) \
        -> jax.Array:
    """NCHW x (C_in, C_out/g, K_h, K_w) -> NCHW TRANSPOSED convolution.

    ``conv2d_transpose(x, w, spec: ConvTransposeSpec, policy=...)`` (or the
    geometry kwargs ``stride= padding= output_padding= dilation= groups=
    layout=``, which build the spec).  The stride is the input (lhs)
    dilation; engines with the ``native_transpose`` capability never build
    the zero-inserted input -- the forward IS the paper's transposed-mode
    tap-GEMM over the mirror regular conv (:func:`transpose_dims`), one
    fused launch across all ``s_h*s_w`` output phases on ``pallas``.  The
    VJP lowers to the already-tested regular-conv engines: dX is the
    mirror strided conv, dW the mirror weight grad with roles swapped.

    ``policy`` selects the engine per pass exactly as for :func:`conv2d`
    (``EnginePolicy`` / policy string / engine name / None for auto), and
    a surrounding :func:`conv_policy` context overrides it.
    ``spec.layout == "NHWC"`` transposes activations at the boundary.
    """
    spec, policy = _canon_transpose_call(args, kwargs)
    policy = _validate_policy(effective_policy(policy))
    if spec.layout == "NHWC":
        y = _mesh_dispatch(_conv2d_transpose, jnp.transpose(x, (0, 3, 1, 2)),
                           w, spec.with_layout("NCHW"), policy)
        return jnp.transpose(y, (0, 2, 3, 1))
    return _mesh_dispatch(_conv2d_transpose, x, w, spec, policy)


# ---------------------------------------------------------------------------
# Public entry point: structured surface + backward-compat shim
# ---------------------------------------------------------------------------

_LEGACY_POSITIONAL = ("stride", "padding", "mode", "groups")


def _deprecated_mode(mode) -> EnginePolicy:
    warnings.warn(
        "conv2d(..., mode=...) is deprecated; pass policy='<engine>' "
        "(uniform) or an EnginePolicy (per-pass) instead",
        DeprecationWarning, stacklevel=4)
    return EnginePolicy.uniform(mode)


def _canon_call(args: tuple, kw: dict) -> tuple[ConvSpec, EnginePolicy | None]:
    """Interpret both call surfaces:

    new:    conv2d(x, w, spec: ConvSpec, policy=...)  (or geometry kwargs)
    legacy: conv2d(x, w, stride, padding, mode, groups)  (mode deprecated)
    """
    spec = kw.pop("spec", None)
    policy = kw.pop("policy", None)
    mode = kw.pop("mode", None)
    geom = {k: kw.pop(k) for k in ("stride", "padding", "dilation", "groups",
                                   "layout") if k in kw}
    if kw:
        raise TypeError(f"conv2d got unexpected kwargs {sorted(kw)}")
    args = list(args)
    if args and isinstance(args[0], ConvSpec):
        if spec is not None:
            raise TypeError("ConvSpec given both positionally and as spec=")
        spec = args.pop(0)
        if args:
            if policy is not None:
                raise TypeError("policy given twice")
            policy = args.pop(0)
        if args:
            raise TypeError("too many positional arguments after ConvSpec")
    elif args and isinstance(args[0], (str, EnginePolicy)):
        # conv2d(x, w, "pallas") / conv2d(x, w, EnginePolicy(...)): a
        # leading policy with default/kwarg geometry (legacy stride is
        # numeric, so this is unambiguous).
        if policy is not None:
            raise TypeError("policy given twice")
        policy = args.pop(0)
        if args:
            raise TypeError("too many positional arguments after policy")
    elif args:
        # Legacy positional (stride, padding, mode, groups).
        if len(args) > len(_LEGACY_POSITIONAL):
            raise TypeError("too many positional arguments")
        for name, val in zip(_LEGACY_POSITIONAL, args):
            if name == "mode":
                if mode is not None:
                    raise TypeError("mode given twice")
                mode = val
            else:
                if name in geom:
                    raise TypeError(f"{name} given twice")
                geom[name] = val
    if mode is not None:
        if policy is not None:
            raise TypeError("pass either policy= or the deprecated mode=, "
                            "not both")
        policy = _deprecated_mode(mode)
    if spec is None:
        spec = ConvSpec.make(**geom)
    elif geom:
        raise TypeError(
            f"geometry given both in the ConvSpec and as kwargs "
            f"{sorted(geom)}; put it all in the spec")
    return spec, policy


def conv2d(x: jax.Array, w: jax.Array, *args, **kwargs) -> jax.Array:
    """NCHW x OIHW -> NCHW convolution with per-pass backprop engines.

    New surface: ``conv2d(x, w, spec: ConvSpec, policy=EnginePolicy | str)``
    (or the non-deprecated geometry kwargs ``stride= padding= dilation=
    groups= layout=``, which build the spec).  ``policy`` is an
    :class:`EnginePolicy`, a policy string (``"fwd=pallas,dgrad=auto,
    wgrad=bp_phase"``), a bare engine name (uniform), or None for the
    ``auto`` default; a surrounding :func:`conv_policy` context overrides
    it.  Legacy surface ``conv2d(x, w, stride, padding, mode, groups)``
    still works; ``mode=`` emits a ``DeprecationWarning``.

    ``spec.layout == "NHWC"`` transposes activations at the boundary
    (weights stay OIHW); everything inside runs NCHW.
    """
    spec, policy = _canon_call(args, kwargs)
    policy = _validate_policy(effective_policy(policy))
    if spec.layout == "NHWC":
        y = _mesh_dispatch(_conv2d, jnp.transpose(x, (0, 3, 1, 2)), w,
                           spec.with_layout("NCHW"), policy)
        return jnp.transpose(y, (0, 2, 3, 1))
    return _mesh_dispatch(_conv2d, x, w, spec, policy)


# ---------------------------------------------------------------------------
# 1-D and depthwise wrappers (Mamba2 / RecurrentGemma temporal convs)
# ---------------------------------------------------------------------------

def _merge_policy(policy, mode):
    if mode is not None:
        if policy is not None:
            raise TypeError("pass either policy= or the deprecated mode=, "
                            "not both")
        return _deprecated_mode(mode)
    return policy


def conv1d(x: jax.Array, w: jax.Array, stride: int = 1, padding=0,
           policy=None, groups: int = 1, dilation: int = 1, *,
           mode=None) -> jax.Array:
    """(B, C, L) x (N, C/g, K) -> (B, N, L_o) through the 2-D engines.

    padding: int (symmetric) or (lo, hi) along the temporal dim.  The
    stride/dilation are applied symmetrically on the degenerate (H=1) axis
    too (a no-op there: one row has no stride phases or dilation gaps).
    """
    policy = _merge_policy(policy, mode)
    if isinstance(padding, int):
        padding = (padding, padding)
    spec = ConvSpec.make(stride=stride, padding=((0, 0), tuple(padding)),
                         dilation=dilation, groups=groups)
    x4 = x[:, :, None, :]
    w4 = w[:, :, None, :]
    y = conv2d(x4, w4, spec, policy)
    return y[:, :, 0, :]


def conv1d_causal(x: jax.Array, w: jax.Array, policy=None,
                  groups: int = 1, *, mode=None) -> jax.Array:
    """Causal (left-pad K-1) stride-1 conv1d: (B, C, L) -> (B, N, L)."""
    k = w.shape[-1]
    return conv1d(x, w, 1, (k - 1, 0), _merge_policy(policy, mode), groups)


def depthwise_causal_conv1d(x: jax.Array, w: jax.Array,
                            policy=None, *, mode=None) -> jax.Array:
    """Causal depthwise conv used by Mamba2: x (B, L, C), w (K, C).

    Lowered as a grouped (groups == C) causal conv1d: the causal shift is an
    asymmetric left-only pad and each channel convolves with its own K-tap
    filter, so the BP-im2col datapath is exercised for the depthwise case
    too.  When every pass of the effective policy resolves inside
    {lax, bp_phase, auto} the layer short-circuits to ONE fused
    ``conv_general_dilated`` with ``feature_group_count``: a stride-1
    backward has no zero-insertion, so the phase decomposition (and the
    auto policy, whose stride-1 rule picks it) degenerates to exactly the
    native conv -- same math, one XLA op on the production hot path.
    """
    b, l, c = x.shape
    k = w.shape[0]
    p = effective_policy(_merge_policy(policy, mode))
    if {p.forward, p.input_grad, p.weight_grad} <= {"lax", "bp_phase", AUTO}:
        xt = x.transpose(0, 2, 1)[:, :, None, :]            # (B, C, 1, L)
        wt = w.T[:, None, None, :]                          # (C, 1, 1, K)
        y = jax.lax.conv_general_dilated(
            xt, wt, (1, 1), [(0, 0), (k - 1, 0)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
            feature_group_count=c)
        return y[:, :, 0, :].transpose(0, 2, 1)
    xt = x.transpose(0, 2, 1)                           # (B, C, L)
    wt = w.T[:, None, :]                                # (C, 1, K)
    y = conv1d_causal(xt, wt, p, groups=c)              # (B, C, L)
    return y.transpose(0, 2, 1)


def output_shape(d: ConvDims) -> tuple[int, int, int, int]:
    return (d.B, d.N, d.H_o, d.W_o)


# ---------------------------------------------------------------------------
# Static introspection: what WOULD dispatch, and why
# ---------------------------------------------------------------------------

def resolve_policy(d: ConvDims, policy=None,
                   transposed: bool = False) -> dict[str, dict[str, str]]:
    """Pure per-pass resolution for one per-group geometry: no arrays, no
    event recording.  ``{pass: {requested, engine, reason}}``.
    ``transposed=True`` resolves over the mirror dims of a transposed conv
    (the planners consulted are role-swapped per pass)."""
    p = _validate_policy(EnginePolicy.coerce(policy) if policy is not None
                         else DEFAULT_POLICY)
    out = {}
    for pass_name, requested in p.slots():
        engine, reason = resolve_engine(requested, pass_name, d, transposed)
        out[pass_name] = {"requested": requested, "engine": engine,
                          "reason": reason}
    return out


def policy_report(x_shape, w_shape, spec=None, policy=None) -> dict:
    """Static dispatch summary for one conv layer under one policy: the
    per-pass engines the resolver would pick (with reasons) plus the Pallas
    tile plans (the planners build per-axis tap tables, so asymmetric
    strides and dilations plan like any other geometry).

    ``spec`` may be a :class:`ConvTransposeSpec` (then ``w_shape`` is the
    transposed ``(C_in, C_out/g, K_h, K_w)`` convention): the report plans
    the MIRROR regular conv the transposed layer role-swaps onto, flags
    ``"transpose": True``, and adds the zero-insertion tap accounting
    (``taps.real`` vs ``taps.zero_inserted``)."""
    from repro.kernels import ops
    if isinstance(spec, ConvTransposeSpec):
        d = transpose_dims(x_shape, w_shape, spec)
        report = {"passes": resolve_policy(d, policy, transposed=True),
                  "spec": str(spec), "transpose": True,
                  "plan": ops.plan_report(d),
                  "taps": transpose_tap_counts(d)}
    else:
        spec = ConvSpec.coerce(spec)
        d = spec_dims(x_shape, w_shape, spec)
        report = {"passes": resolve_policy(d, policy), "spec": str(spec),
                  "transpose": False, "plan": ops.plan_report(d)}
    report["pallas_path"] = all(
        v["engine"] == "pallas" for v in report["passes"].values())
    return report


def conv_plan_report(x_shape, w_shape, stride=1, padding=0,
                     groups: int = 1,
                     budget: int | None = None,
                     dilation=1) -> dict[str, object]:
    """Static Pallas dispatch summary for one conv layer: per-op tile plans
    (spatial/channel tiles, split counts, VMEM footprint) and whether the
    whole layer stays on the Pallas path.  Convenience wrapper over
    ``repro.kernels.ops.plan_report`` taking array shapes instead of a
    ``ConvDims``; pure planner introspection, no arrays are touched."""
    from repro.kernels import ops
    d = make_dims(x_shape, w_shape, stride, padding, groups, dilation)
    return ops.plan_report(d, budget)
