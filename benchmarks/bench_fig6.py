"""Fig. 6: loss/gradient calculation runtime reduction per network.

The paper reports loss-time reductions of 14.5/41.2/16.0/38.3/22.8/79.0 %
and gradient-time reductions of 31.3/76.3/17.7/45.3/20.9/92.4 % across the
evaluated CNNs.  We reproduce the per-network reduction from the analytical
accelerator model over each network's stride>=2 layers.
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs import paper_cnn       # noqa: E402
from benchmarks import perfmodel          # noqa: E402


def run(csv=True):
    rows = []
    for net, layers in paper_cnn.NETWORKS.items():
        loss_bp = loss_tr = grad_bp = grad_tr = 0
        for layer in layers:
            d = paper_cnn.dims(layer)
            rep = perfmodel.report(d)
            loss_bp += rep.loss_bp["total"]
            loss_tr += rep.loss_trad["total"]
            grad_bp += rep.grad_bp["total"]
            grad_tr += rep.grad_trad["total"]
        rows.append({
            "network": net,
            "loss_reduction_pct": round(100 * (1 - loss_bp / loss_tr), 1),
            "grad_reduction_pct": round(100 * (1 - grad_bp / grad_tr), 1),
        })
    avg_l = sum(r["loss_reduction_pct"] for r in rows) / len(rows)
    avg_g = sum(r["grad_reduction_pct"] for r in rows) / len(rows)
    rows.append({"network": "MEAN",
                 "loss_reduction_pct": round(avg_l, 1),
                 "grad_reduction_pct": round(avg_g, 1)})
    if csv:
        print("fig6_network,loss_reduction_pct,grad_reduction_pct")
        for r in rows:
            print(f"{r['network']},{r['loss_reduction_pct']},"
                  f"{r['grad_reduction_pct']}")
    return rows


if __name__ == "__main__":
    run()
