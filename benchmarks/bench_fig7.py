"""Fig. 7: off-chip memory bandwidth occupation reduction.

Paper: loss-calc reduction min 2.34% (SqueezeNet) .. max 54.63% (AlexNet);
grad-calc reduction min 18.98% (ResNet) .. max 31.66% (AlexNet).
Element-exact counting from the traffic accounting in repro.core.
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs import paper_cnn       # noqa: E402
from repro.core import bpim2col, im2col_ref  # noqa: E402


def run(csv=True):
    rows = []
    for net, layers in paper_cnn.NETWORKS.items():
        t_loss = o_loss = t_grad = o_grad = 0
        for layer in layers:
            d = paper_cnn.dims(layer)
            tl = im2col_ref.reorg_traffic_elems_loss(d)
            ol = bpim2col.bp_traffic_elems_loss(d)
            t_loss += tl["offchip_stream"] + tl["reorg_read"] + tl["reorg_write"]
            o_loss += ol["offchip_stream"]
            tg = im2col_ref.reorg_traffic_elems_grad(d)
            og = bpim2col.bp_traffic_elems_grad(d)
            t_grad += tg["offchip_stream"] + tg["reorg_read"] + tg["reorg_write"]
            o_grad += og["offchip_stream"]
        rows.append({
            "network": net,
            "loss_offchip_reduction_pct": round(100 * (1 - o_loss / t_loss), 2),
            "grad_offchip_reduction_pct": round(100 * (1 - o_grad / t_grad), 2),
        })
    if csv:
        print("fig7_network,loss_offchip_reduction_pct,grad_offchip_reduction_pct")
        for r in rows:
            print(f"{r['network']},{r['loss_offchip_reduction_pct']},"
                  f"{r['grad_offchip_reduction_pct']}")
    return rows


if __name__ == "__main__":
    run()
