"""Roofline analysis: three-term model per (arch x shape) from dry-run JSONs.

    compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = per-chip link bytes / 50e9 B/s ICI

Reads reports/dryrun/*.json produced by repro.launch.dryrun; emits the
roofline table (CSV + markdown) with the dominant term, MODEL_FLOPS/HLO
ratio, and the projected step time = max(terms) (the roofline bound).
"""

from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12         # TPU v5e bf16 per chip
HBM_BW = 819e9              # B/s per chip
LINK_BW = 50e9              # B/s per ICI link

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def analytic_memory_bytes(arch: str, shape_name: str) -> float:
    """LOWER-bound global HBM traffic model (perfect fusion):

      train   : optimizer stream (params bf16 r+w, grads r+w, m/v f32 r+w)
                + 3 passes (fwd, bwd, remat) over per-layer activations
                + attention KV re-reads (flash-style, 1024-blocked)
      prefill : params read + 1 activation pass + KV re-reads
      decode  : params(active) read + full KV-cache read per token

    The HLO 'bytes accessed' is the matching UPPER bound (no fusion).
    """
    import jax
    import jax.numpy as jnp
    from functools import partial
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.models import model as M

    cfg = get_config(arch)
    sc = SHAPES[shape_name]
    p_struct = jax.eval_shape(partial(M.init_params, cfg=cfg),
                              jax.random.PRNGKey(0))
    p_bytes = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(p_struct))
    n_params = sum(x.size for x in jax.tree.leaves(p_struct))
    act_b = jnp.dtype(cfg.act_dtype).itemsize
    b, l = sc.global_batch, sc.seq_len
    d, nl = cfg.d_model, cfg.n_layers
    f_eff = cfg.d_ff or 0
    if cfg.n_experts:
        f_eff = cfg.moe_top_k * cfg.moe_d_ff \
            + cfg.n_shared_experts * cfg.moe_d_ff
    if cfg.family == "ssm":
        f_eff = 3 * cfg.ssm_expand * cfg.d_model
    act_per_tok_layer = (10 * d + 3 * f_eff) * act_b
    kv_blocks = max(1, l // 1024)
    kv_per_tok_layer = (0 if cfg.is_attention_free else
                        2 * cfg.n_kv_heads * cfg.head_dim * kv_blocks * act_b)
    head_traffic = b * l * cfg.vocab * act_b            # logits write
    if sc.kind == "train":
        opt = p_bytes * 2 + p_bytes * 2 + 4 * n_params * 4   # p r/w, g r/w, mv r/w
        acts = 3 * b * l * nl * (act_per_tok_layer + kv_per_tok_layer)
        return opt + acts + 2 * head_traffic
    if sc.kind == "prefill":
        return p_bytes + b * l * nl * (act_per_tok_layer + kv_per_tok_layer) \
            + head_traffic
    # decode: one token
    active_frac = 1.0
    if cfg.n_experts:
        active_frac = (cfg.moe_top_k + cfg.n_shared_experts) / cfg.n_experts
        # non-expert params always read
        active_frac = min(1.0, active_frac + 0.3)
    cache = 0.0
    if not cfg.is_attention_free:
        per_tok = (cfg.kv_lora_rank + cfg.qk_rope_head_dim) if cfg.use_mla \
            else 2 * cfg.n_kv_heads * cfg.head_dim
        eff_len = min(l, cfg.local_window) if cfg.local_window else l
        cache = b * eff_len * nl * per_tok * act_b
    return p_bytes * active_frac + cache + b * cfg.vocab * act_b


def load_cells(report_dir: str = REPORT_DIR) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            c = json.load(f)
        parts = os.path.basename(path)[:-5].split("__")
        c["tag"] = parts[3] if len(parts) > 3 else ""
        cells.append(c)
    return cells


def analyze(cell: dict) -> dict:
    chips = cell["n_devices"]
    mf_ = cell.get("model_flops", 0.0)
    hlo_flops = cell["flops"]
    # Scan-mode cells (compile-timeout fallback) count the layer loop body
    # once: HLO flops << model flops.  Use MODEL_FLOPS as the compute floor
    # and flag the ratio as undercounted.
    undercounted = hlo_flops < 0.5 * mf_
    flops_eff = max(hlo_flops, mf_) if undercounted else hlo_flops
    t_compute = flops_eff / (chips * PEAK_FLOPS)
    # Correction: XLA counts a KV-cache dynamic-update-slice as a full
    # read+write of the cache even though the device updates in place; the
    # legitimate full-cache READ by attention remains counted once.
    mem_bytes = cell["bytes_accessed"] - 2 * cell.get("cache_bytes", 0)
    t_memory = max(mem_bytes, 0) / (chips * HBM_BW)
    # collective_bytes['total'] is already per-device link traffic
    t_coll = cell["collective_bytes"]["total"] / LINK_BW
    # analytic lower-bound memory (perfect fusion); HLO bytes = upper bound
    try:
        t_mem_lo = analytic_memory_bytes(cell["arch"], cell["shape"]) \
            / (chips * HBM_BW)
    except Exception:  # noqa: BLE001
        t_mem_lo = float("nan")
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    bound_lo = max(t_compute, t_mem_lo, t_coll)
    dominant_lo = max({"compute": t_compute, "memory": t_mem_lo,
                       "collective": t_coll}.items(), key=lambda kv: kv[1])[0]
    mf = mf_
    ratio = (mf / hlo_flops if hlo_flops > 0 and not undercounted
             else float("nan"))
    _undercounted = undercounted
    # roofline fraction: useful model flops vs what the machine must spend
    # running the compiled program at the dominant bound.
    t_model_ideal = mf / (chips * PEAK_FLOPS)
    frac = t_model_ideal / bound if bound > 0 else float("nan")
    frac_hi = t_model_ideal / bound_lo if bound_lo > 0 else float("nan")
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "policy": cell.get("policy", "tp"),
        "window_skip": cell.get("window_skip", False),
        "tag": cell.get("tag", ""),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_memory_lo_s": t_mem_lo,
        "t_collective_s": t_coll, "dominant": dominant,
        "dominant_lo": dominant_lo,
        "bound_s": bound, "bound_lo_s": bound_lo, "model_flops": mf,
        "model_to_hlo_ratio": ratio, "roofline_fraction": frac,
        "roofline_fraction_hi": frac_hi,
        "undercounted": _undercounted,
    }


def run(csv=True, report_dir: str = REPORT_DIR):
    rows = [analyze(c) for c in load_cells(report_dir)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["policy"]))
    if csv:
        print("roofline_arch,shape,mesh,policy,t_compute_s,t_memory_hlo_s,"
              "t_memory_lo_s,t_collective_s,dominant,model_to_hlo,"
              "roofline_frac_lo,roofline_frac_hi")
        for r in rows:
            print(f"{r['arch']},{r['shape']},{r['mesh']},{r['policy']},"
                  f"{r['t_compute_s']:.4e},{r['t_memory_s']:.4e},"
                  f"{r['t_memory_lo_s']:.4e},"
                  f"{r['t_collective_s']:.4e},{r['dominant_lo']},"
                  f"{r['model_to_hlo_ratio']:.3f},"
                  f"{r['roofline_fraction']:.3f},"
                  f"{r['roofline_fraction_hi']:.3f}")
    return rows


def markdown(report_dir: str = REPORT_DIR, mesh: str = "16x16") -> str:
    rows = [analyze(c) for c in load_cells(report_dir)
            if c["mesh"] == mesh or mesh is None]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"], r["policy"]))
    out = ["| arch | shape | mesh | compute (s) | mem HLO (s) | mem lower "
           "(s) | collective (s) | dominant | model/HLO | frac (lo..hi) |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        tag = f" *({r['tag']})*" if r["tag"] else ""
        if r["undercounted"]:
            tag += " †"
            frac = "n/a †"
            ratio = "n/a †"
        else:
            frac = (f"{r['roofline_fraction']:.2f}.."
                    f"{r['roofline_fraction_hi']:.2f}")
            ratio = f"{r['model_to_hlo_ratio']:.2f}"
        out.append(
            f"| {r['arch']}{tag} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_memory_lo_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant_lo']}** "
            f"| {ratio} | {frac} |")
    out.append("")
    out.append("† compile-timeout cell measured in scan mode: compute term "
               "uses MODEL_FLOPS; loop-internal collectives undercounted; "
               "fraction not comparable.")
    return "\n".join(out)


if __name__ == "__main__":
    run()
