"""Kernel microbenchmarks: wall-clock of the conv backprop engines and the
Pallas kernels (interpret mode) on CPU, plus derived bytes-moved ratios, the
static tile plans the Pallas lanes dispatch with, and the per-pass engines
the ``auto`` policy resolves to.

Two levels are measured per case:
  * raw engine primitives (input_grad_*, weight_grad_*), as before;
  * the end-to-end ``jax.grad`` path through the ``conv2d`` custom_vjp
    under several ``EnginePolicy`` configurations -- the uniform engines,
    ``auto``, and a mixed per-pass policy -- what a training step actually
    runs.

interpret-mode wall-clock is NOT TPU performance; the derived columns
(bytes/elements moved, tile plans, fallback counts, resolved policies) are
the hardware-independent quantities -- they are what future TPU runs
(``BPIM2COL_INTERPRET=0``) compare against.

    PYTHONPATH=src python benchmarks/bench_kernels.py [--tiny] \
        [--json BENCH_kernels.json] [--compare BENCH_kernels.json]

``--tiny`` runs one small shape with 1 rep (the CI smoke lane) and FAILS if
any case falls off the Pallas path: a tile-plan fallback counter > 0 OR the
``auto`` policy resolving any pass of any tiny case to a non-pallas engine.
``--json`` writes the machine-readable record (schema 5): per-case
wall-clock, bytes-moved ratios, tile plans (fits / spatial splits / VMEM
footprint), per-pass auto-policy resolution, the per-case tap counts
(``taps.real`` vs ``taps.materialized`` -- the dilated case's skip_ratio
shows the ~1/(d_h*d_w) zero-skipping), and the planner's hit/fallback
event counts.  The case list includes an asymmetric-stride (2, 3) layer
and a dilated (d=2) layer, both of which the per-axis tap tables keep on
the Pallas path, plus TRANSPOSED-conv forward cases (a stride-2 decoder
stage and a stride-2 + dilated-kernel stage): their records carry
``taps{real, zero_inserted, skip_ratio}`` -- the taps the fused phase
plan runs vs what a stride-1 conv over the physically zero-inserted
input would run, ``skip_ratio ~ 1 - 1/(s_h*s_w)`` -- and the bench FAILS
outright if a transposed case's ``real`` is not strictly below
``zero_inserted``.  The committed ``BENCH_kernels.json`` is the perf
baseline.  ``--compare PATH`` re-runs the bench and exits non-zero if any
shared timing column slowed down by more than ``--tolerance`` (default
35%, re-measured once so only REPRODUCED slowdowns fail -- interpret-mode
CPU wall-clock is long-tailed), any case that previously stayed on the
Pallas path now falls back, or a case's Pallas tap count grew
(zero-skipping regressed -- the gate covers the transposed cases'
``taps.real`` identically).

Schema 5 adds the measured-autotune surface (``repro.config.autotune``):
``--autotune off|measure|cached`` and ``--plan-cache-dir`` set the config
for the run, the record carries an ``autotune`` block (mode / top_k /
reps / cache path), ``plan_time_us = {cold, warm}`` (total planning time
for every case with all in-process caches dropped vs memoized -- in
``measure`` mode "cold" includes on-device candidate timing, in
``cached`` mode it is the persistent-cache read), each case's tile plans
carry ``autotune = {autotuned, measured_us, candidates_timed, cache}``
when a plan went through the tuner, and ``plan_cache_all_hits`` says
every case's every pass resolved from the persistent cache.
``--require-plan-cache-hits`` turns that into a hard gate (the CI smoke
lane's warm second run).

Schema 6 adds the telemetry-overhead columns (``repro.obs``): every case
is re-measured through the SAME jitted ``jax.grad`` path with telemetry
off and then on (bus + trace active), ``telemetry_off_us`` /
``telemetry_on_us`` / ``telemetry_overhead`` (the on/off ratio).  All
obs emission happens at dispatch (trace) time, so the compiled
steady-state cost of enabling telemetry is designed to be zero -- the
disarmed-check idiom -- and ``--compare`` gates the ratio at < 3%
per case (re-measured once, like every wall-clock gate).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import bpim2col, im2col_ref, phase_decomp   # noqa: E402
from repro.core.conv import (conv2d, conv2d_transpose,      # noqa: E402
                             resolve_policy, transpose_dims,
                             transpose_tap_counts)
from repro.core.convspec import ConvSpec, ConvTransposeSpec  # noqa: E402
from repro.core.config import config                        # noqa: E402
from repro.core.im2col_ref import ConvDims                  # noqa: E402
from repro.kernels import autotune, ops                     # noqa: E402

CASES = [
    ConvDims(B=2, C=16, H_i=32, W_i=32, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=2, C=32, H_i=28, W_i=28, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    # Realistic mid-network layer: a >=56x56 spatial plane that previously
    # had to prove the WHOLE plane fits VMEM to stay on the Pallas path.
    ConvDims(B=1, C=128, H_i=56, W_i=56, N=128, K_h=3, K_w=3, S=2,
             P_h=1, P_w=1),
    # Asymmetric stride (2, 3): per-axis tap tables keep it on the Pallas
    # path (pre-PR-4 this was capability-gated onto bp_phase).
    ConvDims(B=2, C=16, H_i=32, W_i=24, N=32, K_h=3, K_w=3, S=2, S_w=3,
             P_h=1, P_w=1),
    # Dilated 3x3 (d=2, effective extent 5): the tap table skips the zero
    # taps, so the Pallas GEMMs run 9 taps, not the materialized 25.
    ConvDims(B=2, C=16, H_i=32, W_i=32, N=32, K_h=5, K_w=5, S=2,
             P_h=2, P_w=2, D_h=2, D_w=2),
]

TINY_CASES = [
    ConvDims(B=1, C=4, H_i=12, W_i=12, N=8, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
]

# Transposed convolution AS A FORWARD LAYER (decoders / GAN generators):
# (x_shape NCHW, w_shape (C_in, C_out/g, K, K), ConvTransposeSpec).  The
# stride is the lhs (input) dilation; the tap-native path runs the fused
# phase plan over the compact input while the zero-insertion lowering
# ("traditional") physically builds the zero-spaced tensor.
TRANSPOSE_CASES = [
    # Stride-2 decoder stage: 16x16 -> 32x32 (pad 1, output_padding 1).
    ((2, 32, 16, 16), (32, 16, 3, 3),
     ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)),
    # Stride-2 + dilated 3x3 kernel (d=2, effective extent 5): lhs AND rhs
    # dilation zero-skipping compose.
    ((2, 16, 16, 16), (16, 16, 3, 3),
     ConvTransposeSpec.make(stride=2, padding=2, output_padding=1,
                            dilation=2)),
]

TINY_TRANSPOSE_CASES = [
    ((1, 8, 8, 8), (8, 4, 3, 3),
     ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)),
]

# End-to-end jax.grad policies: uniform engines (the old mode matrix), the
# shape-dependent auto default, and a mixed per-pass policy exercising three
# different engines in one backward.
GRAD_POLICIES = (
    ("traditional", "traditional"),
    ("bp_im2col", "bp_im2col"),
    ("bp_phase", "bp_phase"),
    ("pallas", "pallas"),
    ("auto", "auto"),
    ("mixed", "fwd=lax,dgrad=pallas,wgrad=bp_phase"),
)

# Transposed-case policies: the zero-insertion materialization baseline
# ("traditional"), the implicit engines, and a mixed per-pass policy.
GRAD_POLICIES_T = (
    ("traditional", "traditional"),
    ("bp_phase", "bp_phase"),
    ("pallas", "pallas"),
    ("auto", "auto"),
    ("mixed", "fwd=pallas,dgrad=bp_phase,wgrad=bp_im2col"),
)


def _t(fn, *args, reps=5):
    """Best-of-``reps`` wall-clock in us (min is the standard
    noise-robust microbenchmark statistic: load spikes on a shared CPU
    only ever INFLATE a sample, so the minimum tracks the true cost and
    keeps the --compare gate from tripping on scheduler noise)."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _spec(d: ConvDims) -> ConvSpec:
    return ConvSpec.make(stride=(d.s_h, d.s_w),
                         padding=((d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)),
                         dilation=(d.D_h, d.D_w))


def _grad_fn(d: ConvDims, policy: str):
    """jit'd jax.grad through the conv2d custom_vjp for one policy."""
    spec = _spec(d)

    @jax.jit
    def g(x, w):
        return jax.grad(
            lambda a, b: jnp.sum(conv2d(a, b, spec, policy) ** 2),
            argnums=(0, 1))(x, w)
    return g


def _bytes_moved(d: ConvDims) -> dict[str, float]:
    """Hardware-independent reorganization traffic: how many elements the
    traditional zero-space datapath moves per compact element (BP-im2col
    moves none of the zero-space)."""
    loss = im2col_ref.reorg_traffic_elems_loss(d)
    grad = im2col_ref.reorg_traffic_elems_grad(d)
    compact = d.B * d.N * d.H_o * d.W_o
    return {
        "loss_offchip_ratio": round(loss["offchip_stream"] / compact, 3),
        "grad_offchip_ratio": round(grad["offchip_stream"] / compact, 3),
        "loss_extra_storage_elems": loss["extra_storage"],
        "grad_extra_storage_elems": grad["extra_storage"],
        "lowered_sparsity": round(bpim2col.lowered_sparsity_loss(d), 3),
    }


def _t_grad_fn(spec: ConvTransposeSpec, policy: str):
    """jit'd jax.grad through the conv2d_transpose custom_vjp."""
    @jax.jit
    def g(x, w):
        return jax.grad(
            lambda a, b: jnp.sum(conv2d_transpose(a, b, spec, policy) ** 2),
            argnums=(0, 1))(x, w)
    return g


#: the telemetry-overhead gate: a case's on/off wall-clock ratio above
#: this fails --compare (re-measured once, like every wall-clock gate).
#: All obs emission is dispatch-time, so a compiled step should not move
#: at all; 3% is pure scheduler-noise headroom.
TELEMETRY_OVERHEAD_MAX = 1.03


def _telemetry_overhead(make_fn, x, w, reps) -> dict[str, float]:
    """Steady-state telemetry cost: the same jax.grad case through a
    FRESH jitted fn with telemetry off vs on (bus + trace active).
    Dispatch-time emission lands in ``_t``'s warmup call (which compiles
    the fresh fn), so the measured reps see exactly what enabling
    telemetry adds to a compiled training step.  The two arms are timed
    back-to-back in INTERLEAVED rounds and the ratio is taken PER ROUND,
    keeping the round with the smallest ratio: a real steady-state cost
    would survive every round, while scheduler noise / CPU-frequency
    drift inflates only some rounds (and both arms of a round equally)."""
    fn_off = make_fn()
    fn_on = None
    best = None                              # (ratio, off_us, on_us)
    reps = max(reps, 20)                     # the 3% gate needs a low floor
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "trace.json")
        for _ in range(5):
            off = _t(fn_off, x, w, reps=reps)
            with config.override(telemetry=True, trace_path=path):
                if fn_on is None:
                    fn_on = make_fn()        # traced with telemetry on
                on = _t(fn_on, x, w, reps=reps)
            if best is None or on / off < best[0]:
                best = (on / off, off, on)
    ratio, off, on = best
    return {"telemetry_off_us": round(off, 1),
            "telemetry_on_us": round(on, 1),
            "telemetry_overhead": round(ratio, 3)}


def run_transpose(csv=True, tcases=None, reps=5,
                  grad_policies=GRAD_POLICIES_T):
    """Timing rows for the transposed (lhs-dilation) forward-layer cases:
    end-to-end forward and jax.grad per policy -- "traditional" is the
    physical zero-insertion materialization the implicit engines avoid."""
    rng = np.random.RandomState(1)
    rows = []
    for x_shape, w_shape, spec in tcases if tcases is not None \
            else TRANSPOSE_CASES:
        x = jnp.asarray(rng.randn(*x_shape), jnp.float32)
        w = jnp.asarray(rng.randn(*w_shape), jnp.float32)
        d = transpose_dims(x_shape, w_shape, spec)
        dil = f"/d{spec.d_h}x{spec.d_w}" if spec.has_dilation else ""
        row = {"case": f"T:{x_shape[2]}/{x_shape[1]}/{w_shape[1]}/"
                       f"{w_shape[2]}/{spec.s_h}x{spec.s_w}/"
                       f"{spec.padding[0][0]}+op{spec.op_h}{dil}"}
        for label, policy in grad_policies:
            fwd = jax.jit(lambda a, b, p=policy:
                          conv2d_transpose(a, b, spec, p))
            row[f"fwdT_{label}_us"] = round(_t(fwd, x, w, reps=reps), 1)
            row[f"gradT_{label}_us"] = round(
                _t(_t_grad_fn(spec, policy), x, w, reps=reps), 1)
        row.update(_telemetry_overhead(
            lambda s=spec: _t_grad_fn(s, "bp_phase"), x, w, reps))
        tap = transpose_tap_counts(d)
        row["taps_skip_ratio"] = tap["skip_ratio"]
        rows.append(row)
    if csv and rows:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def run(csv=True, cases=None, reps=5, grad_policies=GRAD_POLICIES):
    rng = np.random.RandomState(0)
    rows = []
    for d in cases or CASES:
        x = jnp.asarray(rng.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
        # The Pallas engine (and the end-to-end conv2d surface) take the
        # COMPACT kernel; the materializing engines take the zero-dilated
        # effective kernel -- identical arrays when the case is undilated.
        w = jnp.asarray(rng.randn(d.N, d.C, d.k_taps_h, d.k_taps_w),
                        jnp.float32)
        w_eff = im2col_ref.zero_insert(w, (d.D_h, d.D_w)) \
            if d.has_dilation else w
        dy = jnp.asarray(rng.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
        t_trad = _t(jax.jit(lambda a, b: im2col_ref.input_grad_explicit(a, b, d)), dy, w_eff, reps=reps)
        t_bp = _t(jax.jit(lambda a, b: bpim2col.input_grad_implicit(a, b, d)), dy, w_eff, reps=reps)
        t_ph = _t(jax.jit(lambda a, b: phase_decomp.input_grad_phase(a, b, d)), dy, w_eff, reps=reps)
        t_pl = _t(jax.jit(lambda a, b: ops.conv2d_input_grad(a, b, d)), dy, w, reps=reps)
        tg_trad = _t(jax.jit(lambda a, b: im2col_ref.weight_grad_explicit(a, b, d)), x, dy, reps=reps)
        tg_ph = _t(jax.jit(lambda a, b: phase_decomp.weight_grad_phase(a, b, d)), x, dy, reps=reps)
        tg_pl = _t(jax.jit(lambda a, b: ops.conv2d_weight_grad(a, b, d)), x, dy, reps=reps)
        dil = f"/d{d.D_h}x{d.D_w}" if d.has_dilation else ""
        row = {
            "case": f"{d.H_i}/{d.C}/{d.N}/{d.K_h}/{d.s_h}x{d.s_w}/"
                    f"{d.P_h}{dil}",
            "dI_trad_us": round(t_trad, 1),
            "dI_bp_gather_us": round(t_bp, 1),
            "dI_phase_us": round(t_ph, 1),
            "dI_pallas_us": round(t_pl, 1),
            "dI_speedup_phase": round(t_trad / t_ph, 2),
            "dW_trad_us": round(tg_trad, 1),
            "dW_phase_us": round(tg_ph, 1),
            "dW_pallas_us": round(tg_pl, 1),
            "dW_speedup_phase": round(tg_trad / tg_ph, 2),
            "lowered_sparsity": round(bpim2col.lowered_sparsity_loss(d), 3),
        }
        # End-to-end jax.grad through the custom_vjp (the training path).
        for label, policy in grad_policies:
            row[f"grad_{label}_us"] = round(_t(_grad_fn(d, policy), x, w,
                                               reps=reps), 1)
        row.update(_telemetry_overhead(
            lambda dd=d: _grad_fn(dd, "bp_phase"), x, w, reps))
        rows.append(row)
    if csv:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def _auto_resolution(d: ConvDims) -> dict[str, str]:
    """pass -> engine the auto policy resolves to for this geometry."""
    return {p: v["engine"] for p, v in resolve_policy(d, "auto").items()}


def _transpose_record_cases(trows, tcases) -> list[dict]:
    """Per-transposed-case records: the mirror-conv tile plans, the
    zero-insertion tap accounting (``taps.real`` vs ``taps.zero_inserted``
    -- ``skip_ratio ~ 1 - 1/(s_h*s_w)`` is the lhs-dilation skipping), and
    the per-pass auto-policy resolution over the mirror dims."""
    out = []
    for (x_shape, w_shape, spec), row in zip(tcases, trows):
        d = transpose_dims(x_shape, w_shape, spec)
        plan = ops.plan_report(d)
        auto = {p: v["engine"] for p, v in
                resolve_policy(d, "auto", transposed=True).items()}
        taps = transpose_tap_counts(d)
        if taps["real"] >= taps["zero_inserted"]:
            # Structural gate (explicit raise: must not evaporate under
            # python -O the way a bare assert would).
            raise SystemExit(
                "transposed case runs no fewer taps than the zero-inserted "
                f"materialization: {taps}")
        out.append({
            "dims": {"transpose": True, "B": x_shape[0], "C": x_shape[1],
                     "H_i": x_shape[2], "W_i": x_shape[3],
                     "N": w_shape[1] * spec.groups,
                     "K_h": w_shape[2], "K_w": w_shape[3],
                     "S": spec.s_h, "S_w": spec.s_w,
                     "D_h": spec.d_h, "D_w": spec.d_w,
                     "P_h": spec.padding[0][0], "P_w": spec.padding[1][0],
                     "op_h": spec.op_h, "op_w": spec.op_w},
            "timings_us": row,
            "plan": plan,
            "taps": taps,
            "auto_policy": auto,
            "auto_all_pallas": all(e == "pallas" for e in auto.values()),
            "fits": plan["pallas_path"],
            "input_grad_plan_none": not plan["input_grad"].get("fused",
                                                               False),
        })
    return out


def _all_plan_dims(cases, tcases) -> list[ConvDims]:
    """Every ConvDims the record plans: the direct cases plus the
    transposed cases' mirror-conv dims."""
    return list(cases) + [transpose_dims(x_shape, w_shape, spec)
                          for x_shape, w_shape, spec in tcases]


def _measure_plan_time(cases, tcases) -> dict[str, float]:
    """Total wall time (us) to plan EVERY case, cold (in-process plan
    caches dropped: the analytic lru, the tuned-plan memo) then warm
    (everything memoized).  Cold is where autotuning costs live: candidate
    timing in ``measure`` mode, the persistent-cache read in ``cached``
    mode.  Warm is the steady-state cost a training step sees."""
    dims = _all_plan_dims(cases, tcases)

    def once():
        t0 = time.perf_counter()
        for d in dims:
            ops.plan_report(d)
        return (time.perf_counter() - t0) * 1e6

    ops.clear_tile_plan_cache()
    autotune.clear_memo()
    cold = once()
    warm = once()
    return {"cold": round(cold, 1), "warm": round(warm, 1)}


def _plan_cache_all_hits(record_cases) -> bool:
    """True iff every tile plan of every case was served from the
    persistent plan cache (``cache == "hit"``).  Vacuously False when
    autotuning is off (no plan carries the annotation)."""
    seen = False
    for c in record_cases:
        plan = c["plan"]
        subs = [plan["forward"], plan["weight_grad"]]
        if plan["input_grad"].get("fused"):
            subs.append(plan["input_grad"])
        for s in subs:
            at = s.get("autotune")
            if at is None or at["cache"] != "hit":
                return False
            seen = True
    return seen


def _json_record(rows, cases, trows=(), tcases=(),
                 plan_time_us=None) -> dict:
    """Attach the static tile plans + traffic ratios + per-pass auto-policy
    resolution to the timing rows."""
    cases = list(cases)
    record_cases = []
    for d, row in zip(cases, rows):
        plan = ops.plan_report(d)
        auto = _auto_resolution(d)
        real = plan["kernel_taps"]["real"]
        materialized = plan["kernel_taps"]["materialized"]
        record_cases.append({
            "dims": {"B": d.B, "C": d.C, "H_i": d.H_i, "W_i": d.W_i,
                     "N": d.N, "K_h": d.K_h, "K_w": d.K_w, "S": d.S,
                     "S_w": d.S_w, "D_h": d.D_h, "D_w": d.D_w,
                     "P_h": d.P_h, "P_w": d.P_w},
            "timings_us": row,
            "bytes_moved": _bytes_moved(d),
            "plan": plan,
            # Zero-skipping dilation: the tap count the Pallas GEMMs run
            # vs what the kernel-materialization lowering would run.
            "taps": {"real": real, "materialized": materialized,
                     "skip_ratio": round(real / materialized, 3)},
            "auto_policy": auto,
            "auto_all_pallas": all(e == "pallas" for e in auto.values()),
            "fits": plan["pallas_path"],
            "input_grad_plan_none": not plan["input_grad"].get("fused",
                                                               False),
        })
    record_cases.extend(_transpose_record_cases(trows, tcases))
    events = ops.plan_events()
    fallbacks = sum(v for k, v in events.items() if k.endswith("_fallback"))
    return {
        "bench": "bench_kernels",
        "schema": 6,
        "vmem_budget_bytes": config.vmem_budget_bytes,
        "interpret": config.interpret,
        "autotune": {"mode": config.autotune,
                     "top_k": config.autotune_top_k,
                     "reps": config.autotune_reps,
                     "cache_path": autotune.cache_path()},
        "plan_time_us": plan_time_us,
        "cases": record_cases,
        "plan_events": events,
        "tile_plan_fallbacks": fallbacks,
        "pallas_path_all_cases": all(c["fits"] for c in record_cases),
        "auto_policy_all_pallas": all(c["auto_all_pallas"]
                                      for c in record_cases),
        "plan_cache_all_hits": _plan_cache_all_hits(record_cases),
    }


def _case_key(case: dict) -> tuple:
    return tuple(sorted(case["dims"].items()))


def compare_records(record: dict, baseline: dict,
                    tolerance: float = 0.35) -> list[str]:
    """Regressions of ``record`` vs ``baseline``: any shared timing column
    slower by > tolerance, any case leaving the Pallas path, and any pass
    the auto policy used to place on pallas but no longer does."""
    problems = []
    base_cases = {_case_key(c): c for c in baseline.get("cases", [])}
    new_keys = {_case_key(c) for c in record["cases"]}
    for key, b in base_cases.items():
        if key not in new_keys:
            # Dropping a benchmarked shape must not pass vacuously.
            problems.append(
                f"baseline case {dict(b['dims'])} missing from the new "
                "record (case dropped or dims changed?)")
    for c in record["cases"]:
        b = base_cases.get(_case_key(c))
        if b is None:
            continue                        # new case: nothing to compare
        name = c["timings_us"].get("case", str(dict(c["dims"])))
        for col, base_us in b["timings_us"].items():
            if not col.endswith("_us") or not isinstance(base_us,
                                                         (int, float)):
                continue
            if col.startswith("telemetry_"):
                # The off/on arms only exist to form the ratio; their
                # contract is the ABSOLUTE overhead gate below, not a
                # baseline-relative wall-clock diff (the grad_*_us
                # columns already gate this fn's wall-clock).
                continue
            now_us = c["timings_us"].get(col)
            if now_us is None:
                # A renamed/dropped column must not pass vacuously.
                problems.append(
                    f"{name} {col}: present in baseline but missing from "
                    "the new record (renamed or dropped?)")
                continue
            if now_us > base_us * (1.0 + tolerance):
                problems.append(
                    f"{name} {col}: {now_us:.1f}us vs baseline "
                    f"{base_us:.1f}us (+{now_us / base_us - 1.0:.0%} "
                    f"> {tolerance:.0%})")
        if b.get("fits") and not c.get("fits"):
            problems.append(f"{name}: tile plan regressed off the Pallas "
                            "path (fits: true -> false)")
        base_taps, new_taps = b.get("taps"), c.get("taps")
        if base_taps and new_taps and new_taps["real"] > base_taps["real"]:
            # More taps than the baseline means the dilation zero-skipping
            # (or the per-axis table) regressed to a denser enumeration.
            problems.append(
                f"{name}: Pallas tap count regressed "
                f"{base_taps['real']} -> {new_taps['real']}")
        base_auto = b.get("auto_policy", {})
        for pass_name, engine in c.get("auto_policy", {}).items():
            if base_auto.get(pass_name) == "pallas" and engine != "pallas":
                problems.append(
                    f"{name} {pass_name}: auto policy regressed "
                    f"pallas -> {engine}")
        # Telemetry must stay free in compiled steady state (emission is
        # dispatch-time only): an absolute gate, not baseline-relative.
        overhead = c["timings_us"].get("telemetry_overhead")
        if overhead is not None and overhead > TELEMETRY_OVERHEAD_MAX:
            problems.append(
                f"{name} telemetry_overhead: on/off ratio {overhead} > "
                f"{TELEMETRY_OVERHEAD_MAX} (enabling telemetry slowed "
                "the compiled step)")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="one small shape, 1 rep (CI smoke)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable benchmark record")
    ap.add_argument("--compare", metavar="PATH", default=None,
                    help="exit non-zero on regression vs this baseline "
                         "record (slowdown > --tolerance, or a case "
                         "falling off the Pallas path)")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="allowed per-column slowdown for --compare.  The "
                         "default absorbs interpret-mode CPU wall-clock "
                         "bimodality (the structural gates -- Pallas path, "
                         "auto policy, tap counts -- are tolerance-free); "
                         "tighten it for real-TPU comparisons")
    ap.add_argument("--autotune", choices=("off", "measure", "cached"),
                    default=None,
                    help="set repro.config.autotune for this run "
                         "(default: whatever the config/env already says)")
    ap.add_argument("--plan-cache-dir", metavar="DIR", default=None,
                    help="persistent plan-cache directory "
                         "(repro.config.plan_cache_dir)")
    ap.add_argument("--require-plan-cache-hits", action="store_true",
                    help="exit non-zero unless EVERY case's every tile "
                         "plan was served from the persistent plan cache "
                         "(the CI smoke lane's warm second run)")
    args = ap.parse_args()
    updates = {}
    if args.autotune is not None:
        updates["autotune"] = args.autotune
    if args.plan_cache_dir is not None:
        updates["plan_cache_dir"] = args.plan_cache_dir
    if updates:
        config.update(**updates)
    cases = TINY_CASES if args.tiny else CASES
    tcases = TINY_TRANSPOSE_CASES if args.tiny else TRANSPOSE_CASES
    reps = 1 if args.tiny else 10
    ops.clear_tile_plan_cache()
    autotune.clear_memo()
    ops.reset_plan_events()
    rows = run(cases=cases, reps=reps)
    trows = run_transpose(tcases=tcases, reps=reps)
    assert rows and trows and all(
        v > 0 for r in (*rows, *trows) for k, v in r.items()
        if k.endswith("_us")), "bench produced no timings"
    plan_time = _measure_plan_time(cases, tcases)
    record = _json_record(rows, cases, trows, tcases,
                          plan_time_us=plan_time)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    if args.tiny:
        # CI gate (with or without --json): a tiny shape falling off the
        # Pallas path -- by tile-plan fallback OR by the auto policy
        # resolving any pass elsewhere -- is a planner/resolver regression,
        # not a capacity problem.
        if record["tile_plan_fallbacks"] > 0 or \
                not record["pallas_path_all_cases"] or \
                not record["auto_policy_all_pallas"]:
            print(f"FAIL: tile-plan fallbacks="
                  f"{record['tile_plan_fallbacks']}, "
                  f"pallas_path_all_cases="
                  f"{record['pallas_path_all_cases']}, "
                  f"auto_policy_all_pallas="
                  f"{record['auto_policy_all_pallas']}", file=sys.stderr)
            raise SystemExit(1)
    if args.require_plan_cache_hits and not record["plan_cache_all_hits"]:
        at_events = {k: v for k, v in record["plan_events"].items()
                     if "_autotune_" in k}
        print(f"FAIL: --require-plan-cache-hits: not every tile plan was "
              f"served from the persistent plan cache "
              f"(autotune events: {at_events}, mode="
              f"{record['autotune']['mode']})", file=sys.stderr)
        raise SystemExit(1)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        problems = compare_records(record, baseline, args.tolerance)
        if problems:
            # CPU wall-clock is long-tailed on shared machines: re-measure
            # once and keep only the findings that REPRODUCE (a structural
            # regression -- Pallas path, auto policy, tap count -- always
            # does; a scheduler hiccup does not).
            ops.clear_tile_plan_cache()
            ops.reset_plan_events()
            record2 = _json_record(run(csv=False, cases=cases, reps=reps),
                                   cases,
                                   run_transpose(csv=False, tcases=tcases,
                                                 reps=reps),
                                   tcases)
            keys2 = {p.split(":", 1)[0]
                     for p in compare_records(record2, baseline,
                                              args.tolerance)}
            problems = [p for p in problems
                        if p.split(":", 1)[0] in keys2]
        if problems:
            print("PERF REGRESSION vs " + args.compare, file=sys.stderr)
            for p in problems:
                print("  " + p, file=sys.stderr)
            raise SystemExit(1)
        print(f"no regression vs {args.compare} "
              f"(tolerance {args.tolerance:.0%})", file=sys.stderr)


if __name__ == "__main__":
    main()
