"""Kernel microbenchmarks: wall-clock of the three conv backprop engines and
the Pallas kernels (interpret mode) on CPU, plus derived bytes-moved ratios.

interpret-mode wall-clock is NOT TPU performance; the derived columns
(bytes/elements moved) are the hardware-independent quantities.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import bpim2col, im2col_ref, phase_decomp   # noqa: E402
from repro.core.im2col_ref import ConvDims                  # noqa: E402

CASES = [
    ConvDims(B=2, C=16, H_i=32, W_i=32, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=2, C=32, H_i=28, W_i=28, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=1, C=64, H_i=14, W_i=14, N=128, K_h=1, K_w=1, S=2, P_h=0, P_w=0),
]


def _t(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv=True):
    rng = np.random.RandomState(0)
    rows = []
    for d in CASES:
        x = jnp.asarray(rng.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
        w = jnp.asarray(rng.randn(d.N, d.C, d.K_h, d.K_w), jnp.float32)
        dy = jnp.asarray(rng.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
        t_trad = _t(jax.jit(lambda a, b: im2col_ref.input_grad_explicit(a, b, d)), dy, w)
        t_bp = _t(jax.jit(lambda a, b: bpim2col.input_grad_implicit(a, b, d)), dy, w)
        t_ph = _t(jax.jit(lambda a, b: phase_decomp.input_grad_phase(a, b, d)), dy, w)
        tg_trad = _t(jax.jit(lambda a, b: im2col_ref.weight_grad_explicit(a, b, d)), x, dy)
        tg_ph = _t(jax.jit(lambda a, b: phase_decomp.weight_grad_phase(a, b, d)), x, dy)
        sparsity = bpim2col.lowered_sparsity_loss(d)
        rows.append({
            "case": f"{d.H_i}/{d.C}/{d.N}/{d.K_h}/{d.S}/{d.P_h}",
            "dI_trad_us": round(t_trad, 1),
            "dI_bp_gather_us": round(t_bp, 1),
            "dI_phase_us": round(t_ph, 1),
            "dI_speedup_phase": round(t_trad / t_ph, 2),
            "dW_trad_us": round(tg_trad, 1),
            "dW_phase_us": round(tg_ph, 1),
            "dW_speedup_phase": round(tg_trad / tg_ph, 2),
            "lowered_sparsity": round(sparsity, 3),
        })
    if csv:
        print("kern_case,dI_trad_us,dI_bp_us,dI_phase_us,dI_spd,"
              "dW_trad_us,dW_phase_us,dW_spd,sparsity")
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


if __name__ == "__main__":
    run()
