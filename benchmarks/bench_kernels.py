"""Kernel microbenchmarks: wall-clock of the three conv backprop engines and
the Pallas kernels (interpret mode) on CPU, plus derived bytes-moved ratios.

Two levels are measured per case:
  * raw engine primitives (input_grad_*, weight_grad_*), as before;
  * the end-to-end ``jax.grad`` path through the ``conv2d`` custom_vjp --
    what a training step actually runs per mode.

interpret-mode wall-clock is NOT TPU performance; the derived columns
(bytes/elements moved) are the hardware-independent quantities.

    PYTHONPATH=src python benchmarks/bench_kernels.py [--tiny]

``--tiny`` runs one small shape with 1 rep (the CI smoke lane).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import bpim2col, im2col_ref, phase_decomp   # noqa: E402
from repro.core.conv import conv2d                          # noqa: E402
from repro.core.im2col_ref import ConvDims                  # noqa: E402

CASES = [
    ConvDims(B=2, C=16, H_i=32, W_i=32, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=2, C=32, H_i=28, W_i=28, N=32, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=1, C=64, H_i=14, W_i=14, N=128, K_h=1, K_w=1, S=2, P_h=0, P_w=0),
]

TINY_CASES = [
    ConvDims(B=1, C=4, H_i=12, W_i=12, N=8, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
]

GRAD_MODES = ("traditional", "bp_im2col", "bp_phase")


def _t(fn, *args, reps=5):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def _grad_fn(d: ConvDims, mode: str):
    """jit'd jax.grad through the conv2d custom_vjp for one mode."""
    pad = ((d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi))

    @jax.jit
    def g(x, w):
        return jax.grad(
            lambda a, b: jnp.sum(conv2d(a, b, d.S, pad, mode) ** 2),
            argnums=(0, 1))(x, w)
    return g


def run(csv=True, cases=None, reps=5, grad_modes=GRAD_MODES):
    rng = np.random.RandomState(0)
    rows = []
    for d in cases or CASES:
        x = jnp.asarray(rng.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
        w = jnp.asarray(rng.randn(d.N, d.C, d.K_h, d.K_w), jnp.float32)
        dy = jnp.asarray(rng.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
        t_trad = _t(jax.jit(lambda a, b: im2col_ref.input_grad_explicit(a, b, d)), dy, w, reps=reps)
        t_bp = _t(jax.jit(lambda a, b: bpim2col.input_grad_implicit(a, b, d)), dy, w, reps=reps)
        t_ph = _t(jax.jit(lambda a, b: phase_decomp.input_grad_phase(a, b, d)), dy, w, reps=reps)
        tg_trad = _t(jax.jit(lambda a, b: im2col_ref.weight_grad_explicit(a, b, d)), x, dy, reps=reps)
        tg_ph = _t(jax.jit(lambda a, b: phase_decomp.weight_grad_phase(a, b, d)), x, dy, reps=reps)
        sparsity = bpim2col.lowered_sparsity_loss(d)
        row = {
            "case": f"{d.H_i}/{d.C}/{d.N}/{d.K_h}/{d.S}/{d.P_h}",
            "dI_trad_us": round(t_trad, 1),
            "dI_bp_gather_us": round(t_bp, 1),
            "dI_phase_us": round(t_ph, 1),
            "dI_speedup_phase": round(t_trad / t_ph, 2),
            "dW_trad_us": round(tg_trad, 1),
            "dW_phase_us": round(tg_ph, 1),
            "dW_speedup_phase": round(tg_trad / tg_ph, 2),
            "lowered_sparsity": round(sparsity, 3),
        }
        # End-to-end jax.grad through the custom_vjp (the training path).
        for mode in grad_modes:
            row[f"grad_{mode}_us"] = round(_t(_grad_fn(d, mode), x, w,
                                              reps=reps), 1)
        rows.append(row)
    if csv:
        print(",".join(rows[0].keys()))
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="one small shape, 1 rep (CI smoke)")
    args = ap.parse_args()
    if args.tiny:
        rows = run(cases=TINY_CASES, reps=1,
                   grad_modes=GRAD_MODES + ("pallas",))
        assert rows and all(v > 0 for r in rows for k, v in r.items()
                            if k.endswith("_us")), "bench produced no timings"
    else:
        run()


if __name__ == "__main__":
    main()
