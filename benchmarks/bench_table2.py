"""Table II: runtime of loss & gradient calculation for five conv layers.

Compares the analytical accelerator model against the paper's published
cycle counts, and reports measured wall-clock for the JAX engines
(traditional explicit vs BP-im2col implicit vs phase-decomposed) on CPU.
"""

from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import paper_cnn                         # noqa: E402
from repro.core import im2col_ref, phase_decomp     # noqa: E402
from benchmarks import perfmodel                            # noqa: E402

# Paper Table II: (loss_bp, loss_trad_comp, loss_trad_reorg, grad_bp,
#                  grad_trad_comp, grad_trad_reorg)
PAPER = {
    (224, 3, 64, 3, 2, 0): (8962102, 8929989, 37083360, 2416476, 2274645, 37083360),
    (112, 64, 64, 3, 2, 1): (10310400, 10329856, 3798997, 9439744, 8905216, 3798997),
    (56, 256, 512, 1, 2, 0): (9330688, 9125888, 15592964, 11653120, 11636736, 15592964),
    (28, 244, 244, 3, 2, 1): (8081314, 8222247, 1657646, 8575509, 8089919, 1657646),
    (14, 1024, 2048, 1, 2, 0): (11984896, 11059200, 6074461, 15278080, 15245312, 6074461),
}


def _time(fn, *args, reps=3):
    jax.block_until_ready(fn(*args))          # compile + warmup
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv=True):
    rows = []
    rng = np.random.RandomState(0)
    for layer in paper_cnn.TABLE2_LAYERS:
        d = paper_cnn.dims(layer)
        rep = perfmodel.report(d)
        p = PAPER[layer]
        paper_loss_speedup = (p[1] + p[2]) / p[0]
        paper_grad_speedup = (p[4] + p[5]) / p[3]

        # wall-clock of the actual JAX engines (loss calc) on a reduced copy
        ds = im2col_ref.ConvDims(B=1, C=min(d.C, 32), H_i=min(d.H_i, 56),
                                 W_i=min(d.W_i, 56), N=min(d.N, 32),
                                 K_h=d.K_h, K_w=d.K_w, S=d.S,
                                 P_h=d.P_h, P_w=d.P_w)
        dy = jnp.asarray(rng.randn(ds.B, ds.N, ds.H_o, ds.W_o), jnp.float32)
        w = jnp.asarray(rng.randn(ds.N, ds.C, ds.K_h, ds.K_w), jnp.float32)
        t_trad = _time(jax.jit(
            lambda dy, w: im2col_ref.input_grad_explicit(dy, w, ds)), dy, w)
        t_phase = _time(jax.jit(
            lambda dy, w: phase_decomp.input_grad_phase(dy, w, ds)), dy, w)

        rows.append({
            "layer": "/".join(map(str, layer)),
            "model_loss_speedup": round(rep.loss_speedup, 2),
            "paper_loss_speedup": round(paper_loss_speedup, 2),
            "model_grad_speedup": round(rep.grad_speedup, 2),
            "paper_grad_speedup": round(paper_grad_speedup, 2),
            "jax_loss_trad_us": round(t_trad, 1),
            "jax_loss_phase_us": round(t_phase, 1),
            "jax_speedup": round(t_trad / t_phase, 2),
        })
    if csv:
        print("table2_layer,model_loss_spd,paper_loss_spd,model_grad_spd,"
              "paper_grad_spd,jax_trad_us,jax_phase_us,jax_spd")
        for r in rows:
            print(",".join(str(v) for v in r.values()))
    return rows


if __name__ == "__main__":
    run()
