"""Analytical performance model of the paper's TPU-like accelerator.

16x16 systolic array, input-stationary dataflow, FP32, double-buffered A/B
buffers (Section III-C).  The model reproduces the paper's comparison
structure:

  * computation cycles are (near) IDENTICAL between traditional im2col and
    BP-im2col -- the paper's design injects zeros at the PE ports rather
    than skipping MACs ("our design does not support sparse computation at
    this stage");
  * the traditional path pays an additional REORGANIZATION phase (zero-
    insert/pad the compact tensor in DRAM + build the explicit lowered
    copy), modeled as bytes moved / DRAM bytes-per-cycle;
  * bandwidth occupation of off-chip memory and of the on-chip buffers is
    tracked in element counts by repro.core.{im2col_ref,bpim2col} and
    compared as reduction ratios (Figs. 7-8) -- these are exact counting
    results, independent of cycle-model calibration.

Table IV (area) cannot be reproduced without RTL synthesis; the paper's
numbers are carried as constants for reporting (documented deviation).
"""

from __future__ import annotations

import dataclasses

from repro.core import bpim2col, im2col_ref
from repro.core.im2col_ref import ConvDims

PE = 16                      # systolic array dimension
DRAM_BYTES_PER_CYCLE = 16.0  # calibrated: ~GDDR-class interface per cycle
ELEM_BYTES = 4               # FP32 (Section IV)
FILL_DRAIN = 2 * PE          # pipeline fill + drain per stationary tile


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def gemm_cycles(m: int, k: int, cols: int) -> int:
    """Input-stationary GEMM Y(m x cols) = A(m x k) @ B(k x cols).

    B is the stationary operand, loaded tile-by-tile (k/16 x cols/16 tiles,
    load hidden by double buffering); A streams m rows through each tile.
    """
    tiles = _ceil(k, PE) * _ceil(cols, PE)
    return tiles * (max(m, PE) + FILL_DRAIN)


# ---------------------------------------------------------------------------
# Loss calculation (transposed mode)
# ---------------------------------------------------------------------------

def loss_gemm_dims(d: ConvDims) -> tuple[int, int, int]:
    """Y = A(C x N*Kh*Kw) @ B(N*Kh*Kw x B*Hi*Wi) (paper Fig. 2 lowering)."""
    return d.C, d.N * d.K_h * d.K_w, d.B * d.H_i * d.W_i


def loss_cycles_bp(d: ConvDims) -> dict:
    m, k, cols = loss_gemm_dims(d)
    comp = gemm_cycles(m, k, cols)
    return {"compute": comp, "reorg": 0, "total": comp}


def loss_cycles_traditional(d: ConvDims) -> dict:
    m, k, cols = loss_gemm_dims(d)
    comp = gemm_cycles(m, k, cols)
    t = im2col_ref.reorg_traffic_elems_loss(d)
    # reorganization: read compact + write zero-spaced map, then write the
    # explicit lowered matrix copy and read it back for streaming.
    lowered = k * cols
    reorg_bytes = (t["reorg_read"] + t["reorg_write"] + 2 * lowered) * ELEM_BYTES
    reorg = int(reorg_bytes / DRAM_BYTES_PER_CYCLE)
    return {"compute": comp, "reorg": reorg, "total": comp + reorg}


# ---------------------------------------------------------------------------
# Gradient calculation (dilated mode)
# ---------------------------------------------------------------------------

def grad_gemm_dims(d: ConvDims) -> tuple[int, int, int]:
    """Tr(dW) = A(N x B*Ho''*Wo'') @ B(B*Ho''*Wo'' x C*Kh*Kw)."""
    return d.N, d.B * d.H_o2 * d.W_o2, d.C * d.K_h * d.K_w


def grad_cycles_bp(d: ConvDims) -> dict:
    m, k, cols = grad_gemm_dims(d)
    comp = gemm_cycles(m, k, cols)
    return {"compute": comp, "reorg": 0, "total": comp}


def grad_cycles_traditional(d: ConvDims) -> dict:
    m, k, cols = grad_gemm_dims(d)
    comp = gemm_cycles(m, k, cols)
    t = im2col_ref.reorg_traffic_elems_grad(d)
    lowered = k * cols                       # im2col copy of the padded input
    reorg_bytes = (t["reorg_read"] + t["reorg_write"] + 2 * lowered) * ELEM_BYTES
    reorg = int(reorg_bytes / DRAM_BYTES_PER_CYCLE)
    return {"compute": comp, "reorg": reorg, "total": comp + reorg}


# ---------------------------------------------------------------------------
# Bandwidth occupation (Figs. 7-8) -- exact element counting
# ---------------------------------------------------------------------------

def offchip_reduction_loss(d: ConvDims) -> float:
    trad = im2col_ref.reorg_traffic_elems_loss(d)
    ours = bpim2col.bp_traffic_elems_loss(d)
    t_total = trad["offchip_stream"] + trad["reorg_read"] + trad["reorg_write"]
    o_total = ours["offchip_stream"]
    return 1.0 - o_total / t_total


def offchip_reduction_grad(d: ConvDims) -> float:
    trad = im2col_ref.reorg_traffic_elems_grad(d)
    ours = bpim2col.bp_traffic_elems_grad(d)
    t_total = trad["offchip_stream"] + trad["reorg_read"] + trad["reorg_write"]
    return 1.0 - ours["offchip_stream"] / t_total


def buffer_reduction_loss(d: ConvDims) -> float:
    """Buffer-B bandwidth reduction == fraction of lowered entries that are
    zero-space (the paper: 'close to the sparsity of the loss')."""
    return bpim2col.lowered_sparsity_loss(d)


def buffer_reduction_grad(d: ConvDims) -> float:
    return bpim2col.lowered_sparsity_grad(d)


def storage_reduction_loss(d: ConvDims) -> float:
    trad = im2col_ref.reorg_traffic_elems_loss(d)
    return trad["extra_storage"] / trad["reorg_write"]


# ---------------------------------------------------------------------------
# Prologue latency (Table III): divider-chain model
# ---------------------------------------------------------------------------

DIV_LATENCY = 17   # fixed-point divider cycles (pipelined, 16+1)

def prologue_latency() -> dict:
    """Address-generation prologue before the first on-chip buffer address.

    Traditional stationary im2col decode: 3 chained div/mod stages -> 51.
    BP-im2col adds one more divide (compact mapping h'=(h-a)/S) -> 68.
    Dynamic matrix: traditional has consecutive addresses (0); BP dilated
    mode must map all 16 lane addresses -> one divider chain, 68.
    """
    return {
        "traditional": {"loss": {"dynamic": 0, "stationary": 3 * DIV_LATENCY},
                        "grad": {"dynamic": 0, "stationary": 3 * DIV_LATENCY}},
        "bp_im2col": {"loss": {"dynamic": 0, "stationary": 4 * DIV_LATENCY},
                      "grad": {"dynamic": 4 * DIV_LATENCY,
                               "stationary": 3 * DIV_LATENCY}},
    }


# Table IV constants (from the paper; no RTL synthesis in this repo).
AREA_UM2 = {
    "traditional": {"dynamic": 5103, "stationary": 53268},
    "bp_im2col": {"dynamic": 56628, "stationary": 121009},
}


@dataclasses.dataclass
class LayerReport:
    dims: ConvDims
    loss_bp: dict
    loss_trad: dict
    grad_bp: dict
    grad_trad: dict

    @property
    def loss_speedup(self) -> float:
        return self.loss_trad["total"] / self.loss_bp["total"]

    @property
    def grad_speedup(self) -> float:
        return self.grad_trad["total"] / self.grad_bp["total"]


def report(d: ConvDims) -> LayerReport:
    return LayerReport(d, loss_cycles_bp(d), loss_cycles_traditional(d),
                       grad_cycles_bp(d), grad_cycles_traditional(d))
