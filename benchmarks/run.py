"""Benchmark orchestrator: one section per paper table/figure.

Prints ``name,...`` CSV blocks.  Roofline rows appear when dry-run reports
exist (reports/dryrun/*.json).
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")


def main() -> None:
    from benchmarks import (bench_table2, bench_table3, bench_fig6,
                            bench_fig7, bench_fig8, bench_kernels, roofline)

    print("# === Table II: per-layer backprop runtime ===")
    bench_table2.run()
    print("\n# === Table III: prologue latency ===")
    bench_table3.run()
    print("\n# === Fig 6: runtime reduction per network ===")
    bench_fig6.run()
    print("\n# === Fig 7: off-chip bandwidth reduction ===")
    bench_fig7.run()
    print("\n# === Fig 8: buffer bandwidth reduction (sparsity) ===")
    bench_fig8.run()
    print("\n# === Kernel microbenchmarks (CPU wall-clock) ===")
    bench_kernels.run()
    print("\n# === Roofline (from dry-run artifacts) ===")
    try:
        rows = roofline.run()
        if not rows:
            print("(no dry-run reports found; run repro.launch.dryrun)")
    except Exception as e:  # noqa: BLE001
        print(f"(roofline unavailable: {e})")


if __name__ == "__main__":
    main()
