"""Serving benchmark: static wave batching vs continuous batching.

Drives the SAME synthetic open-loop arrival stream through the static
wave engine (``repro.serve.engine``) and the slotted continuous-batching
engine (``repro.serve.continuous``) and records, per engine:

  * ``prefill_tokens_per_s``  -- prompt tokens prefilled per prefill-second
  * ``decode_steps_per_s``    -- USEFUL per-lane decode steps (== generated
                                 tokens) per decode-second; wave batching
                                 burns dispatches on finished lanes, which
                                 this metric charges it for
  * ``p50_latency_s`` / ``p99_latency_s`` -- request submit -> finalize
  * ``occupancy``             -- lane_steps / (decode_steps * max_batch),
                                 the fraction of dispatched lane-slots that
                                 were still generating

The workload is deliberately skewed (alternating short / long ``max_new``)
with arrivals injected mid-flight through the engines' ``on_step`` hook:
exactly the mix where wave batching wastes lanes on stragglers and parks
queued requests at wave boundaries, and where the continuous engine's
admit-on-free-lane policy should win.  Prompts within the stream share one
length so the static engine's left-padding is a no-op and greedy outputs
are comparable token-for-token.

Structural gates (tolerance-free, every run):
  * greedy outputs are TOKEN-IDENTICAL per request across both engines;
  * the continuous engine really ran continuous batching
    (``engine_kind == "continuous"`` and ``inserts > 0`` -- a silent
    fallback to wave batching cannot fake both);
  * continuous beats static on p99 latency AND decode_steps_per_s.
    These two are wall-clock-derived, so a failure is re-measured once
    and only fails when it REPRODUCES (a loaded shared CPU can squeeze
    the dispatch-rate gap for one run; the token and no-fallback gates
    are deterministic and never retried).

    PYTHONPATH=src python benchmarks/bench_serve.py [--tiny] \
        [--json BENCH_serve.json] [--compare BENCH_serve.json]

``--compare PATH`` additionally gates the machine-portable
continuous/static RATIOS against the committed baseline record: p99 and
decode-rate ratios may not regress by more than ``--tolerance`` (default
50%); wall-clock ratio failures are re-measured once so only REPRODUCED
regressions fail (shared-CPU wall-clock is long-tailed).  Absolute
timings are recorded for information but never gated -- they are not
portable across machines.  The committed ``BENCH_serve.json`` is a
``--tiny`` record; CI runs ``--tiny --compare BENCH_serve.json``.

``--trace PATH`` / ``--metrics PATH`` switch the telemetry stack on
(``repro.obs``) for the measured run: the trace carries the
serve:prefill / serve:insert / serve:decode span timeline of BOTH
engines, the metrics JSONL a line per decode tick.  The run then also
asserts the obs report is consistent (legacy counters == bus views)
and that serve spans were actually traced.
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time

import jax
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_smoke_config            # noqa: E402
from repro.models import model as M                   # noqa: E402
from repro.serve.continuous import ContinuousEngine   # noqa: E402
from repro.serve.engine import Engine                 # noqa: E402
from repro.serve.request import Request               # noqa: E402

ENGINES = {"static": Engine, "continuous": ContinuousEngine}

#: workload knobs: equal-length prompts (token-equivalence across the
#: engines), skewed max_new mix (the wave-batching pathology), arrivals
#: every ``arrival_gap`` decode dispatches.
WORKLOAD = dict(arch="smollm-360m", requests=32, max_batch=4,
                prompt_len=8, max_new_mix=(2, 24), arrival_gap=1,
                warmup_requests=3)

TINY_WORKLOAD = dict(arch="smollm-360m", requests=16, max_batch=4,
                     prompt_len=6, max_new_mix=(2, 16), arrival_gap=1,
                     warmup_requests=2)


def _make_requests(wl: dict, seed: int = 0) -> list[Request]:
    """The deterministic request stream (fresh Request objects per call --
    engines mutate them)."""
    cfg = get_smoke_config(wl["arch"])
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab, wl["prompt_len"]).tolist()
               for _ in range(wl["requests"])]
    mix = wl["max_new_mix"]
    return [Request(rid=i, prompt=p, max_new=mix[i % len(mix)])
            for i, p in enumerate(prompts)]


def _percentile(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1,
            max(0, math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[i]


def drive(engine_cls, cfg, params, wl: dict, seed: int = 0) -> dict:
    """Run the open-loop stream through one engine; returns metrics plus
    the per-request greedy outputs.

    Arrival clock: one tick per decode dispatch (the ``on_step`` hook),
    request ``i`` arrives at tick ``i * arrival_gap``.  If the engine goes
    fully idle before the next arrival, the clock jumps there (open-loop
    arrivals never depend on engine progress).  A warmup prefix of
    requests is served first through the SAME engine instance to pay all
    jit compilation outside the measured window.
    """
    max_new_max = max(wl["max_new_mix"])
    eng = engine_cls(cfg, params, max_batch=wl["max_batch"],
                     max_len=wl["prompt_len"] + max_new_max + 2,
                     temperature=0.0, seed=seed)

    # -- warmup: compile prefill / insert / decode off the clock ----------
    for r in _make_requests(wl, seed=seed + 1)[:wl["warmup_requests"]]:
        eng.submit(r)
    eng.run()
    for k in eng.stats:
        eng.stats[k] = 0 if isinstance(eng.stats[k], int) else 0.0
    counters0 = dict(eng.counters)

    reqs = _make_requests(wl, seed=seed)
    arrival = [i * wl["arrival_gap"] for i in range(len(reqs))]
    state = {"tick": 0, "idx": 0}

    def flush(e):
        while (state["idx"] < len(reqs)
               and arrival[state["idx"]] <= state["tick"]):
            e.submit(reqs[state["idx"]])
            state["idx"] += 1

    def on_step(e):
        state["tick"] += 1
        flush(e)

    eng.on_step = on_step
    finished: list[Request] = []
    t0 = time.perf_counter()
    flush(eng)
    while state["idx"] < len(reqs) or eng.queue:
        if not eng.queue and state["idx"] < len(reqs):
            state["tick"] = arrival[state["idx"]]      # engine went idle
            flush(eng)
        finished.extend(eng.run())
    wall = time.perf_counter() - t0
    eng.on_step = None

    assert len(finished) == len(reqs), \
        f"engine lost requests: {len(finished)} of {len(reqs)} finished"
    lat = sorted(r.t_done - r.t_submit for r in finished)
    st = eng.stats
    decode_steps = eng.counters["decode_steps"] - counters0["decode_steps"]
    lane_slots = decode_steps * wl["max_batch"]
    return {
        "engine_kind": getattr(eng, "engine_kind", "static"),
        "wall_s": round(wall, 4),
        "prefill_tokens_per_s": round(
            st["prefill_tokens"] / st["prefill_s"], 1)
            if st["prefill_s"] else 0.0,
        "decode_steps_per_s": round(st["lane_steps"] / st["decode_s"], 1)
            if st["decode_s"] else 0.0,
        "p50_latency_s": round(_percentile(lat, 0.50), 4),
        "p99_latency_s": round(_percentile(lat, 0.99), 4),
        "occupancy": round(st["lane_steps"] / lane_slots, 3)
            if lane_slots else 0.0,
        "tokens": st["tokens"],
        "decode_steps": decode_steps,
        "inserts": eng.counters.get("inserts", 0)
            - counters0.get("inserts", 0),
        "summary": eng.run_summary(),
        "outputs": {r.rid: list(r.out) for r in finished},
        "statuses": {r.rid: r.status for r in finished},
    }


def run(wl: dict, seed: int = 0) -> dict:
    """Both engines over the same stream -> the benchmark record."""
    cfg = get_smoke_config(wl["arch"])
    params = M.build_model(cfg).init(jax.random.PRNGKey(seed))
    res = {name: drive(cls, cfg, params, wl, seed=seed)
           for name, cls in ENGINES.items()}
    s, c = res["static"], res["continuous"]
    tokens_match = s["outputs"] == c["outputs"]
    record = {
        "bench": "bench_serve",
        "schema": 1,
        "workload": {k: list(v) if isinstance(v, tuple) else v
                     for k, v in wl.items()},
        "engines": {name: {k: v for k, v in r.items() if k != "outputs"}
                    for name, r in res.items()},
        "tokens_match": tokens_match,
        # Machine-portable continuous/static ratios -- the --compare gate.
        "ratios": {
            "p99_latency": round(c["p99_latency_s"] / s["p99_latency_s"], 3)
                if s["p99_latency_s"] else float("nan"),
            "decode_steps_per_s": round(
                c["decode_steps_per_s"] / s["decode_steps_per_s"], 3)
                if s["decode_steps_per_s"] else float("nan"),
            "occupancy": round(c["occupancy"] / s["occupancy"], 3)
                if s["occupancy"] else float("nan"),
        },
    }
    return record


def structural_problems(record: dict) -> list[str]:
    """The tolerance-free gates every run must pass."""
    problems = []
    c = record["engines"]["continuous"]
    if not record["tokens_match"]:
        problems.append(
            "greedy outputs differ between the static and continuous "
            "engines (slot surgery or per-lane positions corrupt decode)")
    if c["engine_kind"] != "continuous" or c["inserts"] <= 0:
        problems.append(
            f"continuous engine fell back to wave batching "
            f"(engine_kind={c['engine_kind']!r}, inserts={c['inserts']})")
    if not record["ratios"]["p99_latency"] < 1.0:
        problems.append(
            f"continuous does not beat static on p99 latency "
            f"(ratio {record['ratios']['p99_latency']})")
    if not record["ratios"]["decode_steps_per_s"] > 1.0:
        problems.append(
            f"continuous does not beat static on decode steps/s "
            f"(ratio {record['ratios']['decode_steps_per_s']})")
    return problems


def compare_records(record: dict, baseline: dict,
                    tolerance: float = 0.50) -> list[str]:
    """Ratio regressions vs the committed baseline (the wall-clock part;
    structural gates run separately and are tolerance-free)."""
    problems = []
    if record["workload"] != baseline.get("workload"):
        problems.append(
            f"workload mismatch vs baseline: {record['workload']} != "
            f"{baseline.get('workload')} (regenerate the baseline)")
        return problems
    br = baseline.get("ratios", {})
    r = record["ratios"]
    # p99 ratio: smaller is better -> fail when it GREW past tolerance.
    if r["p99_latency"] > br["p99_latency"] * (1.0 + tolerance):
        problems.append(
            f"p99_latency ratio regressed: {r['p99_latency']} vs baseline "
            f"{br['p99_latency']} (+ more than {tolerance:.0%})")
    # decode-rate ratio: larger is better -> fail when it SHRANK.
    if r["decode_steps_per_s"] < br["decode_steps_per_s"] \
            * (1.0 - tolerance):
        problems.append(
            f"decode_steps_per_s ratio regressed: "
            f"{r['decode_steps_per_s']} vs baseline "
            f"{br['decode_steps_per_s']} (- more than {tolerance:.0%})")
    return problems


def _print_table(record: dict) -> None:
    cols = ("wall_s", "prefill_tokens_per_s", "decode_steps_per_s",
            "p50_latency_s", "p99_latency_s", "occupancy", "tokens")
    print("engine," + ",".join(cols))
    for name, r in record["engines"].items():
        print(name + "," + ",".join(str(r[k]) for k in cols))
    print(f"ratios(continuous/static): {record['ratios']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="small stream (the CI smoke lane)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the machine-readable benchmark record")
    ap.add_argument("--compare", metavar="PATH", default=None,
                    help="exit non-zero on ratio regression vs this "
                         "baseline record")
    ap.add_argument("--tolerance", type=float, default=0.50,
                    help="allowed relative drift of the continuous/static "
                         "ratios for --compare (structural gates are "
                         "tolerance-free)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable telemetry and write a Perfetto "
                         "trace_event JSON of both engines' span timeline")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="enable telemetry and stream per-decode-tick "
                         "metrics JSONL to PATH")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.trace or args.metrics:
        from repro.core.config import config
        config.update(telemetry=True, trace_path=args.trace,
                      metrics_path=args.metrics)
    wl = TINY_WORKLOAD if args.tiny else WORKLOAD
    record = run(wl, seed=args.seed)
    _print_table(record)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}", file=sys.stderr)
    problems = structural_problems(record)
    if problems:
        # Token identity and the no-fallback gate are deterministic; the
        # two "continuous beats static" gates ride wall clock, so confirm
        # a failure on a fresh measurement before failing the run.
        record2 = run(wl, seed=args.seed)
        second = structural_problems(record2)
        problems = [p for p in problems
                    if p.split("(", 1)[0] in
                    {q.split("(", 1)[0] for q in second}]
    if problems:
        print("STRUCTURAL FAILURE", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        raise SystemExit(1)
    if args.compare:
        with open(args.compare) as f:
            baseline = json.load(f)
        problems = compare_records(record, baseline, args.tolerance)
        if problems:
            # Wall-clock ratios are long-tailed on shared CPUs: re-measure
            # once and keep only findings that REPRODUCE.
            record2 = run(wl, seed=args.seed)
            second = set(compare_records(record2, baseline,
                                         args.tolerance))
            problems = [p for p in problems
                        if p.split(":", 1)[0] in
                        {q.split(":", 1)[0] for q in second}]
        if problems:
            print("PERF REGRESSION vs " + args.compare, file=sys.stderr)
            for p in problems:
                print("  " + p, file=sys.stderr)
            raise SystemExit(1)
        print(f"no regression vs {args.compare} "
              f"(tolerance {args.tolerance:.0%})", file=sys.stderr)
    if args.trace or args.metrics:
        from repro import obs
        rep = obs.finalize()
        print(f"obs: {rep['events_total']} events {rep['events_by_kind']} "
              f"trace={rep['trace_file']} "
              f"metrics={rep['metrics']['lines']} lines", file=sys.stderr)
        assert rep["consistent"], (
            "telemetry divergence: " + "; ".join(rep["divergences"]))
        if args.trace:
            assert rep["trace"]["spans_by_prefix"].get("serve", 0) > 0, \
                "telemetry on but no serve spans were traced"


if __name__ == "__main__":
    main()
