"""Table III: prologue latency of the address-generation modules.

Divider-chain model (Section C of perfmodel): the paper reports 51 cycles
for the traditional stationary module and 68/51 for BP-im2col (stationary
loss / gradient) plus 68 for the BP dynamic module in gradient mode.
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from benchmarks import perfmodel          # noqa: E402

PAPER = {
    "traditional": {"loss": {"dynamic": 0, "stationary": 51},
                    "grad": {"dynamic": 0, "stationary": 51}},
    "bp_im2col": {"loss": {"dynamic": 0, "stationary": 68},
                  "grad": {"dynamic": 68, "stationary": 51}},
}


def run(csv=True):
    model = perfmodel.prologue_latency()
    rows = []
    for algo in ("traditional", "bp_im2col"):
        for calc in ("loss", "grad"):
            for mod in ("dynamic", "stationary"):
                rows.append({
                    "module": f"{algo}/{calc}/{mod}",
                    "model_cycles": model[algo][calc][mod],
                    "paper_cycles": PAPER[algo][calc][mod],
                })
    if csv:
        print("table3_module,model_cycles,paper_cycles")
        for r in rows:
            print(f"{r['module']},{r['model_cycles']},{r['paper_cycles']}")
    return rows


if __name__ == "__main__":
    run()
