"""Fig. 8: on-chip buffer bandwidth occupation reduction + sparsity overlay.

Paper: buffer-B loss-calc reductions 93.90/75.36/75.45/75.04/70.56/76.15 %,
buffer-A grad-calc reductions 94.23/76.67/74.70/74.15/74.53/76.30 %, both
'close to the sparsity of the loss of the output'.  These ARE the lowered-
matrix sparsities, which we compute exactly per layer (Eqs. (2)-(4)).
"""

from __future__ import annotations

import sys

sys.path.insert(0, "src")

from repro.configs import paper_cnn       # noqa: E402
from repro.core import bpim2col           # noqa: E402


def run(csv=True):
    rows = []
    for net, layers in paper_cnn.NETWORKS.items():
        num_l = den_l = num_g = den_g = 0.0
        for layer in layers:
            d = paper_cnn.dims(layer)
            rl, cl = d.lowered_B_shape_loss()
            tot_l = rl * cl
            num_l += bpim2col.lowered_sparsity_loss(d) * tot_l
            den_l += tot_l
            tot_g = d.B * d.H_o2 * d.W_o2 * d.N
            num_g += bpim2col.lowered_sparsity_grad(d) * tot_g
            den_g += tot_g
        rows.append({
            "network": net,
            "bufferB_loss_reduction_pct": round(100 * num_l / den_l, 2),
            "bufferA_grad_reduction_pct": round(100 * num_g / den_g, 2),
        })
    if csv:
        print("fig8_network,bufferB_loss_reduction_pct,"
              "bufferA_grad_reduction_pct")
        for r in rows:
            print(f"{r['network']},{r['bufferB_loss_reduction_pct']},"
                  f"{r['bufferA_grad_reduction_pct']}")
    return rows


if __name__ == "__main__":
    run()
