"""Deterministic fallback for ``hypothesis`` when it isn't installed.

CI installs the real package (see pyproject ``[dev]``); this stub keeps the
property tests *runnable* in minimal environments by replaying a fixed
pseudo-random sample of the strategy space instead of failing at collection.
Only the tiny API surface the test-suite uses is provided: ``given``,
``settings`` and ``strategies.integers``.
"""

from __future__ import annotations


import random
import types

IS_STUB = True


class _IntStrategy:
    def __init__(self, min_value: int, max_value: int):
        self.min_value = min_value
        self.max_value = max_value

    def draw(self, rnd: random.Random) -> int:
        return rnd.randint(self.min_value, self.max_value)


def integers(min_value: int, max_value: int) -> _IntStrategy:
    return _IntStrategy(min_value, max_value)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # No functools.wraps: pytest must NOT see the strategy parameters in
        # the signature (it would try to resolve them as fixtures).
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", 20)
            rnd = random.Random(0xB91)  # fixed seed: reproducible sample
            for _ in range(n):
                draw = {k: s.draw(rnd) for k, s in strats.items()}
                fn(**draw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.pytestmark = list(getattr(fn, "pytestmark", []))
        return wrapper
    return deco


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = integers
