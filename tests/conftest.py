import os
import sys

# Tests run on the host's single CPU device (the dry-run sets its own 512-
# device flag in a separate process; never set it here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
