import os
import sys

# Tests run on the host's single CPU device (the dry-run sets its own 512-
# device flag in a separate process; never set it here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.dirname(__file__))

try:  # real hypothesis when available (CI installs it via the dev extra)
    import hypothesis  # noqa: F401
except ImportError:  # minimal env: deterministic replay stub
    import types

    import _hypothesis_stub as _stub

    _mod = types.ModuleType("hypothesis")
    _mod.given = _stub.given
    _mod.settings = _stub.settings
    _mod.strategies = _stub.strategies
    _mod.IS_STUB = True
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _stub.strategies

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(autouse=True)
def _clean_introspection():
    """Every test starts with clean introspection state everywhere -- the
    legacy counters (dispatch/quarantine/plan/fault/checkpoint) and the
    obs bus/trace/metrics window -- via the one covering reset."""
    from repro import obs
    obs.reset_all()
    yield
