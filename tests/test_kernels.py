"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, strides and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.im2col_ref import ConvDims, conv2d_lax, conv_grads_lax
from repro.kernels import ops, ref
from repro.kernels.matmul import matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels import tap_gemm as tg

CONV_CASES = [
    ConvDims(B=2, C=3, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=1, C=2, H_i=9, W_i=9, N=3, K_h=3, K_w=3, S=2, P_h=0, P_w=0),
    ConvDims(B=1, C=2, H_i=8, W_i=8, N=3, K_h=1, K_w=1, S=2, P_h=0, P_w=0),
    ConvDims(B=2, C=2, H_i=12, W_i=12, N=3, K_h=3, K_w=3, S=3, P_h=1, P_w=1),
    ConvDims(B=1, C=3, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=1, P_h=1, P_w=1),
    ConvDims(B=1, C=130, H_i=6, W_i=6, N=140, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    # Asymmetric strides: per-axis tap tables (s_h x s_w phase grid).
    ConvDims(B=1, C=3, H_i=10, W_i=12, N=4, K_h=3, K_w=3, S=2, S_w=3,
             P_h=1, P_w=1),
    ConvDims(B=2, C=2, H_i=9, W_i=12, N=3, K_h=3, K_w=3, S=1, S_w=2,
             P_h=0, P_w=1),
]


def _data(d, dtype=jnp.float32, seed=0):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(d.B, d.C, d.H_i, d.W_i), dtype)
    w = jnp.asarray(r.randn(d.N, d.C, d.K_h, d.K_w), dtype)
    dy = jnp.asarray(r.randn(d.B, d.N, d.H_o, d.W_o), dtype)
    return x, w, dy


@pytest.mark.parametrize("d", CONV_CASES,
                         ids=lambda d: f"S{d.s_h}x{d.s_w}K{d.K_h}C{d.C}N{d.N}")
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
class TestConvKernels:
    def test_forward(self, d, dtype):
        x, w, dy = _data(d, dtype)
        tol = 2e-4 if dtype == jnp.float32 else 6e-2
        got = ops.conv2d_forward(x, w, d)
        want = conv2d_lax(x.astype(jnp.float32), w.astype(jnp.float32), d)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=tol, atol=tol * 10)

    def test_input_grad(self, d, dtype):
        x, w, dy = _data(d, dtype)
        tol = 2e-4 if dtype == jnp.float32 else 6e-2
        want, _ = conv_grads_lax(x.astype(jnp.float32),
                                 w.astype(jnp.float32),
                                 dy.astype(jnp.float32), d)
        got = ops.conv2d_input_grad(dy, w, d)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=tol, atol=tol * 10)

    def test_weight_grad(self, d, dtype):
        x, w, dy = _data(d, dtype)
        tol = 2e-3 if dtype == jnp.float32 else 1e-1
        _, want = conv_grads_lax(x.astype(jnp.float32),
                                 w.astype(jnp.float32),
                                 dy.astype(jnp.float32), d)
        got = ops.conv2d_weight_grad(x, dy, d)
        np.testing.assert_allclose(np.asarray(got, np.float32), want,
                                   rtol=tol, atol=tol * 20)


@pytest.mark.parametrize("m,k,n", [(64, 64, 64), (200, 300, 150),
                                   (128, 256, 128), (1, 7, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
def test_matmul_kernel(m, k, n, dtype):
    r = np.random.RandomState(1)
    a = jnp.asarray(r.randn(m, k), dtype)
    b = jnp.asarray(r.randn(k, n), dtype)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(matmul(a, b), np.float32),
        np.asarray(ref.matmul_ref(a, b), np.float32), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("b,h,lq,lk,dd,causal", [
    (2, 4, 128, 128, 64, True),
    (1, 2, 200, 200, 32, True),
    (1, 2, 100, 100, 32, False),
    (1, 2, 1, 77, 32, True),          # decode-style
    (1, 1, 64, 64, 128, True),
])
def test_flash_attention(b, h, lq, lk, dd, causal):
    r = np.random.RandomState(2)
    q = jnp.asarray(r.randn(b, h, lq, dd), jnp.float32)
    k = jnp.asarray(r.randn(b, h, lk, dd), jnp.float32)
    v = jnp.asarray(r.randn(b, h, lk, dd), jnp.float32)
    got = flash_attention(q, k, v, causal=causal)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_tap_gemm_against_oracle():
    r = np.random.RandomState(3)
    src = jnp.asarray(r.randn(4, 2, 6, 6, 8), jnp.float32)
    taps = [(0, 0, 0), (1, 0, 1), (2, 1, 0), (3, 1, 1)]
    w = jnp.asarray(r.randn(len(taps), 8, 16), jnp.float32)
    got = tg.tap_gemm(src, w, taps, 5, 5, cin_tile=8, cout_tile=16)
    want = ref.tap_gemm_ref(src, w, taps, 5, 5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_tap_wgrad_against_oracle():
    r = np.random.RandomState(4)
    src = jnp.asarray(r.randn(4, 3, 6, 6, 8), jnp.float32)
    taps = [(0, 0, 0), (1, 0, 1), (2, 1, 0)]
    dy = jnp.asarray(r.randn(3, 5, 5, 16), jnp.float32)
    got = tg.tap_wgrad(src, dy, taps, 5, 5, cin_tile=8, cout_tile=16)
    want = ref.tap_wgrad_ref(src, dy, taps, 5, 5)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(hi=st.integers(4, 12), k=st.integers(1, 3), s=st.integers(1, 3),
       c=st.integers(1, 4), n=st.integers(1, 4), seed=st.integers(0, 999))
def test_property_pallas_matches_lax(hi, k, s, c, n, seed):
    p = min(k - 1, 1)
    if hi + 2 * p < k:
        return
    d = ConvDims(B=1, C=c, H_i=hi, W_i=hi, N=n, K_h=k, K_w=k,
                 S=s, P_h=p, P_w=p)
    if d.H_o < 1:
        return
    x, w, dy = _data(d, seed=seed)
    want_y = conv2d_lax(x, w, d)
    di, dw = conv_grads_lax(x, w, dy, d)
    np.testing.assert_allclose(ops.conv2d_forward(x, w, d), want_y,
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(ops.conv2d_input_grad(dy, w, d), di,
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(ops.conv2d_weight_grad(x, dy, d), dw,
                               rtol=5e-3, atol=5e-3)
