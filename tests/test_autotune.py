"""Measured autotuning + persistent plan cache (kernels/autotune.py).

The timing harness and cache run in interpret mode here -- the timings
are CPU-interpreter numbers, but every code path (candidate racing,
persistence, revalidation, annotation) is the one a TPU run takes.
"""

import json

import numpy as np
import pytest

from repro.core.config import config
from repro.core.im2col_ref import ConvDims, conv2d_lax, conv_grads_lax
from repro.kernels import autotune, ops

import jax.numpy as jnp

D = ConvDims(B=1, C=4, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=2, P_h=1, P_w=1)


@pytest.fixture(autouse=True)
def _tuned(tmp_path):
    """Every test runs with a private plan cache and autotune=measure;
    config (and the caches keyed on it) restored afterwards."""
    saved = config.snapshot()
    config.update(autotune="measure", autotune_top_k=3, autotune_reps=1,
                  plan_cache_dir=str(tmp_path))
    yield tmp_path
    config.update(**saved)


def _fresh():
    ops.clear_tile_plan_cache()
    autotune.clear_memo()
    ops.reset_plan_events()


# ---------------------------------------------------------------------------
# Candidate shortlist
# ---------------------------------------------------------------------------

def test_candidates_head_is_the_analytic_winner():
    with config.override(autotune="off"):
        analytic = ops.forward_plan(D)
    cands = ops.plan_candidates("forward", D, k=3)
    assert 1 <= len(cands) <= 3
    assert cands[0].tile_key == analytic.tile_key
    assert all(c.fits and c.bytes_needed <= config.vmem_budget_bytes
               for c in cands)
    keys = [c.tile_key for c in cands]
    assert len(set(keys)) == len(keys), f"duplicate candidates: {keys}"


def test_candidates_cover_all_roles():
    for role in ops.PLAN_ROLES:
        cands = ops.plan_candidates(role, D, k=2)
        assert cands, role
        if role == "input_grad":
            assert all(isinstance(c, ops.PhasePlan) for c in cands)
        else:
            assert all(isinstance(c, ops.TilePlan) for c in cands)


def test_unknown_role_raises():
    with pytest.raises(ValueError, match="unknown plan role"):
        ops.plan_candidates("sideways", D)
    with pytest.raises(ValueError, match="unknown plan role"):
        ops.plan_from_tile("sideways", D, None, (1, 1, 1, 1))


# ---------------------------------------------------------------------------
# Measurement picks a winner from the shortlist
# ---------------------------------------------------------------------------

def test_measure_picks_a_timed_candidate():
    _fresh()
    plan = ops.forward_plan(D)
    assert plan.autotuned and plan.cache == "miss"
    assert plan.measured_us > 0
    cands = ops.plan_candidates("forward", D)
    assert plan.candidates_timed == len(cands)
    assert plan.tile_key in {c.tile_key for c in cands}
    ev = ops.plan_events()
    assert ev.get("forward_autotune_miss") == 1
    assert ev.get("forward_pallas") == 1       # analytic accounting intact


def test_all_three_planners_route_through_the_tuner():
    _fresh()
    assert ops.forward_plan(D).autotuned
    assert ops.weight_grad_plan(D).autotuned
    ig = ops.input_grad_plan(D)
    assert ig is not None and ig.tile.autotuned and ig.tile.measured_us > 0


def test_infeasible_plans_never_tune():
    """fits=False (forward/wgrad) and None (input_grad) pass through the
    tuner untouched -- there is nothing to race."""
    _fresh()
    with config.override(vmem_budget_bytes=1):
        fp = ops.forward_plan(D)
        assert not fp.fits and not fp.autotuned and fp.cache == ""
        assert ops.input_grad_plan(D) is None
    assert not ops.plan_events().get("forward_autotune_miss")


def test_measure_plan_times_any_candidate():
    for role in ops.PLAN_ROLES:
        cand = ops.plan_candidates(role, D, k=1)[0]
        us = autotune.measure_plan(role, D, cand, reps=1)
        assert np.isfinite(us) and us > 0, (role, us)


# ---------------------------------------------------------------------------
# Persistent cache round-trip
# ---------------------------------------------------------------------------

def test_persistent_round_trip(tmp_path):
    _fresh()
    first = ops.forward_plan(D)
    assert first.cache == "miss"
    path = autotune.cache_path()
    assert path.startswith(str(tmp_path))
    store = json.load(open(path))
    assert store["schema"] == autotune.CACHE_SCHEMA
    assert len(store["entries"]) == 1
    # New process equivalent: drop the in-process caches, keep the disk.
    _fresh()
    second = ops.forward_plan(D)
    assert second.cache == "hit" and second.autotuned
    assert second.tile_key == first.tile_key
    assert second.measured_us == pytest.approx(first.measured_us)
    assert second.candidates_timed == first.candidates_timed
    assert ops.plan_events().get("forward_autotune_hit") == 1


def test_cached_mode_serves_winners_without_timing():
    _fresh()
    ops.forward_plan(D)                        # measure + persist
    _fresh()
    with config.override(autotune="cached"):
        hit = ops.forward_plan(D)
        assert hit.cache == "hit" and hit.autotuned
        # A shape never measured: analytic plan, annotated as a miss.
        other = ConvDims(B=1, C=4, H_i=10, W_i=10, N=4, K_h=3, K_w=3, S=2,
                         P_h=1, P_w=1)
        miss = ops.forward_plan(other)
        assert miss.cache == "miss" and not miss.autotuned
        assert not (ops.plan_events().get("forward_autotune_stale") or 0)
    # cached mode must not have grown the store.
    assert len(autotune._load_store()["entries"]) == 1


def test_off_mode_bypasses_the_tuner_entirely():
    _fresh()
    with config.override(autotune="off"):
        plan = ops.forward_plan(D)
        assert not plan.autotuned and plan.cache == ""
        assert "autotune" not in ops.plan_report(D)["forward"]


def test_cache_key_separates_roles_budgets_and_dims():
    k1 = autotune.plan_key("forward", D, 1 << 20)
    assert k1 != autotune.plan_key("weight_grad", D, 1 << 20)
    assert k1 != autotune.plan_key("forward", D, 1 << 21)
    d2 = ConvDims(B=1, C=4, H_i=10, W_i=8, N=4, K_h=3, K_w=3, S=2,
                  P_h=1, P_w=1)
    assert k1 != autotune.plan_key("forward", d2, 1 << 20)


# ---------------------------------------------------------------------------
# Corrupt / stale tolerance
# ---------------------------------------------------------------------------

def test_corrupt_cache_file_re_tunes():
    _fresh()
    ops.forward_plan(D)
    with open(autotune.cache_path(), "w") as f:
        f.write("{not json")
    _fresh()
    plan = ops.forward_plan(D)                 # no crash: treated as cold
    assert plan.autotuned and plan.cache == "miss"
    store = json.load(open(autotune.cache_path()))  # and re-persisted
    assert store["entries"]


def test_wrong_schema_is_a_cold_cache():
    _fresh()
    ops.forward_plan(D)
    store = json.load(open(autotune.cache_path()))
    store["schema"] = autotune.CACHE_SCHEMA + 1
    with open(autotune.cache_path(), "w") as f:
        json.dump(store, f)
    _fresh()
    assert ops.forward_plan(D).cache == "miss"


@pytest.mark.parametrize("bad_tile", [
    [999, 999, 3, 3],          # does not fit the geometry
    [0, 0, 0, 0],              # degenerate
    ["x", 1, 1, 1],            # garbage types
    [],                        # wrong arity
])
def test_stale_entry_re_tunes(bad_tile):
    _fresh()
    ops.forward_plan(D)
    store = json.load(open(autotune.cache_path()))
    (key,) = store["entries"]
    store["entries"][key]["tile"] = bad_tile
    with open(autotune.cache_path(), "w") as f:
        json.dump(store, f)
    _fresh()
    plan = ops.forward_plan(D)
    assert plan.autotuned and plan.cache == "stale"
    assert ops.plan_events().get("forward_autotune_stale") == 1
    # The re-tuned winner replaced the bad entry.
    healed = json.load(open(autotune.cache_path()))
    assert healed["entries"][key]["tile"] == list(plan.tile_key)


def test_budget_shrink_invalidates_persisted_plans():
    """A winner tuned under a big budget must not be served under a small
    one: plan_from_tile revalidates bytes_needed <= budget."""
    _fresh()
    big = ops.forward_plan(D)
    _fresh()
    with config.override(vmem_budget_bytes=big.bytes_needed - 1):
        plan = ops.forward_plan(D)
        assert plan.fits      # re-planned under the smaller budget
        assert plan.bytes_needed < big.bytes_needed


# ---------------------------------------------------------------------------
# Reporting surface
# ---------------------------------------------------------------------------

def test_plan_report_carries_autotune_fields():
    _fresh()
    rep = ops.plan_report(D)
    for role in ("forward", "weight_grad", "input_grad"):
        at = rep[role]["autotune"]
        assert at["autotuned"] is True
        assert at["cache"] in ("hit", "miss", "stale")
        assert at["measured_us"] > 0
        assert at["candidates_timed"] >= 1
    # And through the shape-level wrapper (the public conv surface): after
    # dropping the in-process caches the persisted winners serve as hits.
    ops.clear_tile_plan_cache()
    autotune.clear_memo()
    from repro.core.conv import conv_plan_report
    rep2 = conv_plan_report((D.B, D.C, D.H_i, D.W_i),
                            (D.N, D.C, D.K_h, D.K_w), 2, 1)
    assert rep2["forward"]["autotune"]["cache"] == "hit"


def test_auto_engine_resolver_consults_tuned_plans():
    """resolve_engine sees the tuned planners exactly as the analytic
    ones: a tuned-fits shape resolves every pass to pallas."""
    from repro.core.conv import resolve_policy
    _fresh()
    res = resolve_policy(D, "auto")
    assert all(v["engine"] == "pallas" for v in res.values()), res
    ev = ops.plan_events()
    assert any("_autotune_" in k for k in ev), ev


# ---------------------------------------------------------------------------
# Gradient-equivalence oracle: tuned plans compute the same math
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [
    D,
    ConvDims(B=2, C=8, H_i=12, W_i=10, N=8, K_h=3, K_w=3, S=2, S_w=3,
             P_h=1, P_w=1),
    ConvDims(B=1, C=4, H_i=12, W_i=12, N=4, K_h=5, K_w=5, S=2,
             P_h=2, P_w=2, D_h=2, D_w=2),
])
def test_autotuned_plans_match_lax_gradients(d):
    _fresh()
    r = np.random.RandomState(7)
    x = jnp.asarray(r.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
    w = jnp.asarray(r.randn(d.N, d.C, d.k_taps_h, d.k_taps_w), jnp.float32)
    dy = jnp.asarray(r.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
    from repro.core.im2col_ref import zero_insert
    w_eff = zero_insert(w, (d.D_h, d.D_w)) if d.has_dilation else w
    want_y = conv2d_lax(x, w_eff, d)
    want_di, want_dw = conv_grads_lax(x, w_eff, dy, d)
    y = ops.conv2d_forward(x, w, d)
    di = ops.conv2d_input_grad(dy, w, d)
    dw = ops.conv2d_weight_grad(x, dy, d)
    assert ops.forward_plan(d).autotuned        # the tuned path really ran
    np.testing.assert_allclose(y, want_y, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(di, want_di, rtol=5e-4, atol=5e-4)
    if d.has_dilation:
        want_dw = want_dw[..., ::d.D_h, ::d.D_w]
    np.testing.assert_allclose(dw, want_dw, rtol=5e-3, atol=5e-3)


def test_every_candidate_computes_identical_results():
    """The racing itself is safe: every shortlisted plan produces the same
    numbers (only the dispatch geometry differs)."""
    r = np.random.RandomState(3)
    x = jnp.asarray(r.randn(D.B, D.C, D.H_i, D.W_i), jnp.float32)
    w = jnp.asarray(r.randn(D.N, D.C, D.K_h, D.K_w), jnp.float32)
    dy = jnp.asarray(r.randn(D.B, D.N, D.H_o, D.W_o), jnp.float32)
    ref_y = ref_di = ref_dw = None
    for fwd, ig, wg in zip(ops.plan_candidates("forward", D, k=3),
                           ops.plan_candidates("input_grad", D, k=3),
                           ops.plan_candidates("weight_grad", D, k=3)):
        y = ops.conv2d_forward(x, w, D, plan=fwd)
        di = ops.conv2d_input_grad(dy, w, D, plan=ig)
        dw = ops.conv2d_weight_grad(x, dy, D, plan=wg)
        if ref_y is None:
            ref_y, ref_di, ref_dw = y, di, dw
            continue
        np.testing.assert_allclose(y, ref_y, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(di, ref_di, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(dw, ref_dw, rtol=1e-4, atol=1e-4)
