"""The observability subsystem: disarmed-path zero-cost contract, bus
consistency with the legacy counters, span tracing + Perfetto export,
the metrics stream, the covering reset, and the docs gate."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.config import config
from repro.core import conv
from repro.core.convspec import ConvSpec
from repro.serve.engine import SUMMARY_COUNTERS, merged_summary


def _x(b=1):
    return jnp.asarray(np.random.RandomState(0).randn(b, 3, 16, 16),
                       jnp.float32)


def _w():
    return jnp.asarray(np.random.RandomState(1).randn(8, 3, 3, 3) * 0.1,
                       jnp.float32)


SPEC = ConvSpec.make(stride=2, padding=1)


# ---------------------------------------------------------------------------
# Disarmed path: telemetry off must be literally free
# ---------------------------------------------------------------------------

def test_disarmed_records_nothing():
    assert not obs.enabled()
    conv.conv2d(_x(), _w(), SPEC, "bp_phase")
    assert conv.dispatch_events()                 # legacy surface records
    assert obs.events.events() == []              # the bus does not
    assert obs.events.counters("dispatch") == {}
    obs.events.emit("dispatch", "anything")       # no-op, no raise
    assert obs.events.events() == []


def test_disarmed_span_is_shared_null_singleton():
    # The inject.py idiom: no per-call allocation on the disabled path.
    assert not obs.trace.active()
    assert obs.trace.span("a", k=1) is obs.trace.span("b")
    d = conv.spec_dims((1, 3, 16, 16), (8, 3, 3, 3), SPEC)
    assert obs.trace.dispatch_span("fwd", "bp_phase", d) \
        is obs.trace.span("c")


def test_disarmed_metrics_write_nothing(tmp_path):
    obs.metrics.train_step(0, {"loss": 1.0})
    obs.metrics.record_latency(0.1)
    assert obs.metrics.lines_written() == 0
    assert not obs.metrics.active()


# ---------------------------------------------------------------------------
# The bus: legacy counters == bus views, exactly
# ---------------------------------------------------------------------------

def test_bus_matches_dispatch_events():
    with config.override(telemetry=True):
        assert obs.enabled()
        conv.conv2d(_x(), _w(), SPEC, "bp_phase")
        conv.conv2d(_x(), _w(), SPEC, "lax")
        legacy = conv.dispatch_events()
        assert legacy and obs.events.counters("dispatch") == legacy
        rep = obs.report()
        assert rep["consistent"], rep["divergences"]
        assert rep["events_by_kind"]["dispatch"] == sum(legacy.values())
    assert not obs.enabled()                      # override restored


def test_bus_sees_degradation_arc():
    with config.override(telemetry=True,
                         fault_spec="pallas.forward.launch:raise",
                         fault_seed=0):
        conv.conv2d(_x(), _w(), SPEC, "pallas")
        bus = obs.events.counters("dispatch")
        assert bus == conv.dispatch_events()
        assert any("->" in name for name in bus), bus   # the degrade edge
        fired = obs.events.events("fault")
        assert fired and fired[0]["tags"]["action"] == "raise"
        assert obs.report()["consistent"]


def test_legacy_reset_drops_bus_kind():
    # The consistency contract under resets: reset_dispatch_events drops
    # the bus's dispatch events too, so the views can never desync.
    with config.override(telemetry=True):
        conv.conv2d(_x(), _w(), SPEC, "bp_phase")
        obs.events.emit("train", "marker")
        conv.reset_dispatch_events()
        assert obs.events.counters("dispatch") == {} == \
            conv.dispatch_events()
        assert [e["name"] for e in obs.events.events()] == ["marker"]
        assert obs.report()["consistent"]


def test_report_flags_divergence():
    with config.override(telemetry=True):
        obs.events.emit("dispatch", "forward:ghost")   # bus-only event
        rep = obs.report()
        assert not rep["consistent"]
        assert any("ghost" in d for d in rep["divergences"])


def test_unknown_kind_raises_when_enabled():
    with config.override(telemetry=True):
        with pytest.raises(ValueError, match="unregistered event kind"):
            obs.events.emit("nope", "x")


def test_bus_overflow_is_counted_not_silent(monkeypatch):
    monkeypatch.setattr(obs.events, "MAX_EVENTS", 3)
    with config.override(telemetry=True):
        for i in range(5):
            obs.events.emit("train", f"e{i}")
        assert len(obs.events.events()) == 3
        assert obs.events.dropped() == 2
        rep = obs.report()
        assert rep["events_dropped"] == 2
        # Saturated bus: the divergence check is skipped, not failed.
        assert rep["consistent"]


# ---------------------------------------------------------------------------
# Spans: nesting, annotations, Perfetto export
# ---------------------------------------------------------------------------

def test_trace_export_validates(tmp_path):
    out = tmp_path / "trace.json"
    with config.override(telemetry=True, trace_path=str(out)):
        with obs.trace.span("outer", step=0):
            with obs.trace.span("inner"):
                conv.conv2d(_x(), _w(), SPEC, "bp_phase")
        assert obs.trace.export() == str(out)
    doc = json.loads(out.read_text())
    from scripts.validate_trace import validate_trace
    problems, stats = validate_trace(doc)
    assert problems == []
    assert "outer" in stats["b_names"] and "inner" in stats["b_names"]
    conv_spans = [n for n in stats["b_names"] if n.startswith("conv:")]
    assert conv_spans, stats["b_names"]
    assert doc["otherData"]["producer"] == "repro.obs.trace"


def test_conv_span_annotations():
    d = conv.spec_dims((2, 3, 16, 16), (8, 3, 3, 3), SPEC)
    ann = obs.trace.conv_annotations(d)
    assert ann["taps"] == {"real": 9, "materialized": 9}
    assert ann["skip_ratio"] == 0.0
    assert ann["bytes_moved"] > 0
    # Dilated case: the tap table runs 9 real taps of a materialized 25.
    dd = conv.spec_dims((1, 3, 16, 16), (8, 3, 3, 3),
                        ConvSpec.make(stride=2, padding=2, dilation=2))
    ann = obs.trace.conv_annotations(dd)
    assert ann["taps"] == {"real": 9, "materialized": 25}
    assert ann["skip_ratio"] == round(1 - 9 / 25, 6)


def test_transposed_span_skip_ratio_matches_tap_counts():
    from repro.core.convspec import ConvTransposeSpec
    tspec = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)
    d = conv.transpose_dims((1, 8, 8, 8), (8, 4, 3, 3), tspec)
    taps = conv.transpose_tap_counts(d)
    ann = obs.trace.conv_annotations(d, transposed=True)
    assert ann["taps"]["real"] == taps["real"]
    assert ann["taps"]["materialized"] == taps["zero_inserted"]


def test_validate_trace_rejects_broken_nesting():
    from scripts.validate_trace import validate_trace
    lane = {"pid": 1, "tid": 1}
    bad = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 1.0, **lane},
        {"name": "b", "ph": "B", "ts": 2.0, **lane},
        {"name": "a", "ph": "E", "ts": 3.0, **lane},   # crosses "b"
    ]}
    problems, _ = validate_trace(bad)
    assert any("must nest" in p for p in problems)
    assert any("left open" in p for p in problems)


# ---------------------------------------------------------------------------
# Metrics stream
# ---------------------------------------------------------------------------

def test_metrics_train_step_lines(tmp_path):
    out = tmp_path / "m.jsonl"
    with config.override(telemetry=True, metrics_path=str(out)):
        conv.conv2d(_x(), _w(), SPEC, "bp_phase")
        obs.metrics.train_step(0, {"loss": 1.5, "grad_norm": 0.2},
                               step_s=0.01)
        obs.metrics.train_step(1, {"loss": 1.2})
        assert obs.metrics.lines_written() == 2
    lines = [json.loads(ln) for ln in out.read_text().splitlines()]
    assert [ln["step"] for ln in lines] == [0, 1]
    assert all(ln["kind"] == "train_step" and "ts" in ln for ln in lines)
    assert lines[0]["loss"] == 1.5 and lines[0]["step_s"] == 0.01
    assert lines[0]["dispatch_mix"].get("bp_phase", 0) > 0
    assert "plan_cache_hit_rate" in lines[0]


def test_metrics_serve_tick(tmp_path):
    class _Stub:
        engine_kind = "static"
        max_batch = 4
        counters = {"decode_steps": 5, "completed": 2, "timed_out": 1,
                    "failed": 0}
        stats = {"lane_steps": 12, "tokens": 20, "decode_s": 0.5}

    out = tmp_path / "m.jsonl"
    with config.override(telemetry=True, metrics_path=str(out)):
        for lat in (0.1, 0.2, 0.3):
            obs.metrics.record_latency(lat)
        obs.metrics.serve_tick(_Stub())
    line = json.loads(out.read_text().splitlines()[0])
    assert line["kind"] == "serve_tick"
    assert line["engine"] == "static"
    assert line["occupancy"] == round(12 / (5 * 4), 4)
    assert line["decode_tok_s"] == round(20 / 0.5, 2)
    assert line["p50_s"] == 0.2 and line["p99_s"] == 0.3
    assert line["timed_out"] == 1


# ---------------------------------------------------------------------------
# Shared serve summary vocabulary
# ---------------------------------------------------------------------------

def test_merged_summary_keys_align_across_engines():
    static = merged_summary("static", {"completed": 1, "waves": 2,
                                       "decode_steps": 3},
                            {"prefill_s": 0.12345678, "tokens": 7})
    cont = merged_summary("continuous", {"completed": 1, "admitted": 2,
                                         "inserts": 2, "decode_steps": 3},
                          {"prefill_s": 0.2, "tokens": 7})
    assert set(static) == set(cont)               # directly diffable
    for key in SUMMARY_COUNTERS:
        assert key in static and key in cont
    assert static["inserts"] == 0 and cont["waves"] == 0  # 0, not absent
    assert static["engine_kind"] == "static"
    assert static["prefill_s"] == 0.123457        # floats rounded


# ---------------------------------------------------------------------------
# The covering reset + the docs gate
# ---------------------------------------------------------------------------

def test_reset_all_covers_every_surface():
    from repro.ft import inject
    with config.override(telemetry=True,
                         fault_spec="pallas.forward.launch:raise",
                         fault_seed=0):
        conv.conv2d(_x(), _w(), SPEC, "pallas")   # faults + degrades
        assert conv.dispatch_events() and inject.fired_events()
        assert obs.events.events()
        obs.reset_all()
        assert conv.dispatch_events() == {}
        assert inject.fired_events() == []
        assert not conv.quarantined_engines()
        assert obs.events.events() == [] and obs.events.dropped() == 0


def test_docs_taxonomy_matches_registry():
    import scripts.check_obs_events as chk
    assert chk.main(["check_obs_events"]) == 0
