"""Core algorithm tests: Algorithms 1 & 2, NZ detection, sparsity claims."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import bpim2col as bp
from repro.core import im2col_ref as ref
from repro.core import phase_decomp as ph
from repro.core.im2col_ref import ConvDims

CASES = [
    ConvDims(B=2, C=3, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=2, P_h=1, P_w=1),
    ConvDims(B=2, C=3, H_i=9, W_i=9, N=4, K_h=3, K_w=3, S=2, P_h=0, P_w=0),
    ConvDims(B=1, C=2, H_i=8, W_i=8, N=3, K_h=1, K_w=1, S=2, P_h=0, P_w=0),
    ConvDims(B=2, C=2, H_i=12, W_i=12, N=3, K_h=3, K_w=3, S=3, P_h=1, P_w=1),
    ConvDims(B=1, C=2, H_i=11, W_i=11, N=2, K_h=5, K_w=5, S=2, P_h=2, P_w=2),
    ConvDims(B=2, C=3, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=1, P_h=1, P_w=1),
    # paper Table II layer 1 geometry (remainder case), tiny channels
    ConvDims(B=1, C=2, H_i=16, W_i=16, N=3, K_h=3, K_w=3, S=2, P_h=0, P_w=0),
]


def _data(d, rng):
    x = jnp.asarray(rng.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
    w = jnp.asarray(rng.randn(d.N, d.C, d.K_h, d.K_w), jnp.float32)
    dy = jnp.asarray(rng.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
    return x, w, dy


@pytest.mark.parametrize("d", CASES, ids=lambda d: f"S{d.S}K{d.K_h}P{d.P_h}H{d.H_i}")
class TestAgainstLax:
    def test_forward_explicit(self, d, rng):
        x, w, _ = _data(d, rng)
        np.testing.assert_allclose(ref.conv2d_lax(x, w, d),
                                   ref.conv2d_forward_explicit(x, w, d),
                                   rtol=2e-4, atol=2e-4)

    def test_input_grad_all_engines(self, d, rng):
        x, w, dy = _data(d, rng)
        want, _ = ref.conv_grads_lax(x, w, dy, d)
        for name, got in {
            "traditional": ref.input_grad_explicit(dy, w, d),
            "bp_im2col": bp.input_grad_implicit(dy, w, d),
            "bp_phase": ph.input_grad_phase(dy, w, d),
        }.items():
            np.testing.assert_allclose(want, got, rtol=2e-4, atol=2e-4,
                                       err_msg=name)

    def test_weight_grad_all_engines(self, d, rng):
        x, w, dy = _data(d, rng)
        _, want = ref.conv_grads_lax(x, w, dy, d)
        for name, got in {
            "traditional": ref.weight_grad_explicit(x, dy, d),
            "bp_im2col": bp.weight_grad_implicit(x, dy, d),
            "bp_phase": ph.weight_grad_phase(x, dy, d),
        }.items():
            np.testing.assert_allclose(want, got, rtol=2e-3, atol=2e-3,
                                       err_msg=name)


def test_algorithm1_nz_against_explicit_map(rng):
    """Every virtual matrix-B entry gathered by Algorithm 1 equals the
    corresponding entry of the explicitly zero-spaced lowered matrix."""
    d = CASES[0]
    _, _, dy = _data(d, rng)
    got = bp.gather_lowered_B_loss(dy, d)
    dy_ei = ref.zero_insert_pad(dy, d)
    a = ref.im2col(dy_ei, d.K_h, d.K_w, 1)        # (B*Hi*Wi, N*Kh*Kw)
    want = a.T                                    # (N*Kh*Kw, B*Hi*Wi)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_algorithm2_nz_against_explicit_map(rng):
    d = CASES[0]
    _, _, dy = _data(d, rng)
    got = bp.gather_lowered_A_grad(dy, d)
    dyi = ref.zero_insert(dy, d.S).transpose(1, 0, 2, 3)  # (N,B,Ho'',Wo'')
    want = dyi.reshape(d.N, -1)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_sparsity_claims_stride2():
    """Paper Section II: zero-pixel ratio 75%..93.91% (loss) and
    74.8%..93.6% (grad) for popular CNN stride>=2 layers."""
    from repro.configs import paper_cnn
    for net, layers in paper_cnn.NETWORKS.items():
        for layer in layers:
            d = paper_cnn.dims(layer)
            sl = bp.lowered_sparsity_loss(d)
            sg = bp.lowered_sparsity_grad(d)
            assert 0.70 <= sl <= 0.95, (net, layer, sl)
            assert 0.70 <= sg <= 0.95, (net, layer, sg)


@pytest.mark.parametrize("d", CASES, ids=lambda d: f"S{d.S}K{d.K_h}P{d.P_h}H{d.H_i}")
def test_lowered_sparsity_loss_against_materialized(d, rng):
    """`lowered_sparsity_loss` (analytic count) == the zero fraction of the
    actually-materialized lowered matrix B (brute force).  Strictly-nonzero
    dy guarantees every zero entry in the lowered matrix is structural."""
    dy = jnp.asarray(np.abs(rng.randn(d.B, d.N, d.H_o, d.W_o)) + 0.5,
                     jnp.float32)
    lowered = np.asarray(bp.gather_lowered_B_loss(dy, d))
    brute = float((lowered == 0.0).mean())
    assert abs(brute - bp.lowered_sparsity_loss(d)) < 1e-9, (
        d, brute, bp.lowered_sparsity_loss(d))


def test_lowered_sparsity_grad_against_materialized(rng):
    d = CASES[0]
    dy = jnp.asarray(np.abs(rng.randn(d.B, d.N, d.H_o, d.W_o)) + 0.5,
                     jnp.float32)
    a = np.asarray(bp.gather_lowered_A_grad(dy, d))
    brute = float((a == 0.0).mean())
    assert abs(brute - bp.lowered_sparsity_grad(d)) < 1e-9


def test_null_addresses_marked():
    d = CASES[0]
    addr = jnp.arange(np.prod(d.lowered_B_shape_loss()), dtype=jnp.int32)
    ok, out = bp.algorithm1(addr, d)
    ok = np.asarray(ok)
    out = np.asarray(out)
    assert (out[~ok] == -1).all()          # NULL poisoning
    size = d.B * d.N * d.H_o * d.W_o
    assert (out[ok] >= 0).all() and (out[ok] < size).all()


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(
    hi=st.integers(4, 14), k=st.integers(1, 4), s=st.integers(1, 3),
    b=st.integers(1, 2), c=st.integers(1, 3), n=st.integers(1, 3),
    p=st.integers(0, 2), seed=st.integers(0, 2**16),
)
def test_property_all_engines_match_lax(hi, k, s, b, c, n, p, seed):
    """Property: for ANY valid conv geometry, both implicit engines produce
    jax.grad's exact gradients (the system invariant of the paper)."""
    if p > k - 1 or hi + 2 * p < k:
        return
    d = ConvDims(B=b, C=c, H_i=hi, W_i=hi, N=n, K_h=k, K_w=k,
                 S=s, P_h=p, P_w=p)
    if d.H_o < 1:
        return
    d.validate()
    r = np.random.RandomState(seed)
    x, w, dy = _data(d, r)
    di, dw = ref.conv_grads_lax(x, w, dy, d)
    np.testing.assert_allclose(di, bp.input_grad_implicit(dy, w, d),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(di, ph.input_grad_phase(dy, w, d),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dw, ph.weight_grad_phase(x, dy, d),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(dw, bp.weight_grad_implicit(x, dy, d),
                               rtol=5e-3, atol=5e-3)
