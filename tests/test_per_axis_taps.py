"""Per-axis tap tables on the Pallas path: asymmetric strides and
zero-skipping dilation.

PR 4's tentpole invariants:
  * the Pallas planners/kernels serve ``s_h != s_w`` via independent
    row/column tap tables (phase grid ``s_h x s_w``, one fused launch);
  * dilation is tap-native: the compact kernel enters the engine and the
    zero taps are skipped at PLAN time (``k_h*k_w`` GEMMs, never
    ``K_eff_h*K_eff_w``), while the kernel-MATERIALIZATION lowering (the
    pre-PR-4 behaviour, still what every non-native engine gets) stays
    registered as the cross-check oracle;
  * ``"auto"`` keeps asymmetric-stride and dilated specs on the pallas
    engine instead of capability-gating them to ``bp_phase``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ConvSpec, conv2d, dispatch_events,
                        reset_dispatch_events, spec_dims)
from repro.core import phase_decomp
from repro.core.im2col_ref import ConvDims, zero_insert
from repro.kernels import ops
from repro.kernels import tap_gemm as tg


def _data(d: ConvDims, seed=0):
    """Compact-kernel data: w has the k_taps (undilated) spatial extent."""
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
    w = jnp.asarray(r.randn(d.N, d.C, d.k_taps_h, d.k_taps_w), jnp.float32)
    dy = jnp.asarray(r.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
    return x, w, dy


def _materialized_oracle(x, w, dy, d):
    """The kernel-materialization lowering, applied by hand: dense phase
    decomposition over the zero-dilated kernel, real dW taps sliced back
    out.  This is exactly what the dispatcher does for engines without
    ``native_dilation`` -- the cross-check oracle for the tap-native path."""
    w_eff = zero_insert(w, (d.D_h, d.D_w)) if d.has_dilation else w
    di = phase_decomp.input_grad_phase(dy, w_eff, d)
    dw = phase_decomp.weight_grad_phase(x, dy, d)
    if d.has_dilation:
        dw = dw[..., ::d.D_h, ::d.D_w]
    return di, dw


# ---------------------------------------------------------------------------
# Deterministic grid: (s_h != s_w) x (d_h, d_w > 1), fast lane
# ---------------------------------------------------------------------------

GRID_DIMS = [
    ConvDims(B=2, C=3, H_i=10, W_i=12, N=4, K_h=3, K_w=3, S=1, S_w=2,
             P_h=1, P_w=1),
    ConvDims(B=2, C=3, H_i=12, W_i=10, N=4, K_h=3, K_w=3, S=3, S_w=2,
             P_h=1, P_w=1),
    ConvDims(B=1, C=2, H_i=12, W_i=12, N=3, K_h=5, K_w=5, S=2,
             P_h=2, P_w=2, D_h=2, D_w=2),
    ConvDims(B=1, C=2, H_i=14, W_i=11, N=3, K_h=5, K_w=3, S=2, S_w=3,
             P_h=2, P_w=1, D_h=2, D_w=1),
    ConvDims(B=1, C=2, H_i=13, W_i=13, N=3, K_h=3, K_w=7, S=3, S_w=1,
             P_h=1, P_w=3, D_h=1, D_w=3),
    ConvDims(B=1, C=2, H_i=12, W_i=12, N=3, K_h=5, K_w=5, S=1,
             P_h=2, P_w=2, D_h=2, D_w=2),
]


@pytest.mark.parametrize(
    "d", GRID_DIMS,
    ids=lambda d: f"s{d.s_h}x{d.s_w}_d{d.D_h}x{d.D_w}")
def test_pallas_matches_materialization_oracle(d):
    """ops-level equivalence: the tap-native Pallas path == the
    kernel-materialization oracle, per pass."""
    x, w, dy = _data(d)
    assert ops.plan_report(d)["pallas_path"], d
    di_want, dw_want = _materialized_oracle(x, w, dy, d)
    np.testing.assert_allclose(ops.conv2d_input_grad(dy, w, d), di_want,
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(ops.conv2d_weight_grad(x, dy, d), dw_want,
                               rtol=5e-3, atol=5e-3)
    # ...and anchor BOTH against lax with rhs_dilation (the spec's native
    # semantics): forward directly, the oracle via XLA's autodiff.
    def f(x_, w_):
        return jax.lax.conv_general_dilated(
            x_, w_, (d.s_h, d.s_w), [(d.P_h, d.p_h_hi), (d.P_w, d.p_w_hi)],
            rhs_dilation=(d.D_h, d.D_w),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
    want_y, vjp = jax.vjp(f, x, w)
    np.testing.assert_allclose(ops.conv2d_forward(x, w, d), want_y,
                               rtol=5e-4, atol=5e-4)
    di_lax, dw_lax = vjp(dy)
    np.testing.assert_allclose(di_want, di_lax, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dw_want, dw_lax, rtol=5e-3, atol=5e-3)


def test_dilated_tap_tables_skip_zero_taps():
    """The FLOPs claim: a (d_h, d_w) dilation cuts the tap count to the
    real taps -- ~1/(d_h*d_w) of the materialized extent."""
    d = ConvDims(B=1, C=4, H_i=16, W_i=16, N=4, K_h=5, K_w=5, S=2,
                 P_h=2, P_w=2, D_h=2, D_w=2)
    taps = ops.forward_plan(d).taps
    assert len(taps) == d.k_taps_h * d.k_taps_w == 9   # not K_eff^2 == 25
    # Every enumerated tap sits on a real kernel position.
    d_dense = ConvDims(B=1, C=4, H_i=16, W_i=16, N=4, K_h=5, K_w=5, S=2,
                       P_h=2, P_w=2)
    assert set(taps) < set(ops.forward_plan(d_dense).taps)
    # The fused input-grad plan skips them too: its total tap count may
    # not exceed the dense plan's.
    ig = ops.input_grad_plan(d)
    ig_dense = ops.input_grad_plan(d_dense)
    n_taps = sum(len(t) for t in ig.phase_taps)
    n_dense = sum(len(t) for t in ig_dense.phase_taps)
    assert n_taps < n_dense, (n_taps, n_dense)
    rep = ops.plan_report(d)
    assert rep["kernel_taps"] == {"real": 9, "materialized": 25}


def test_asym_stride_phase_split_roundtrip():
    r = np.random.RandomState(0)
    x = jnp.asarray(r.randn(2, 11, 13, 3), jnp.float32)
    for s in ((1, 2), (2, 3), (3, 1), (2, 2)):
        planes = ops._phase_split(x, s)
        assert planes.shape[0] == s[0] * s[1]
        back = ops._phase_unsplit(planes, s, 11, 13)
        np.testing.assert_array_equal(back, x)


@pytest.mark.parametrize("s", [(1, 2), (2, 3), (3, 2)])
def test_asym_input_grad_is_one_fused_launch(s, monkeypatch):
    """Asymmetric strides keep the fused single-dispatch property."""
    d = ConvDims(B=1, C=4, H_i=12, W_i=12, N=5, K_h=3, K_w=3, S=s[0],
                 S_w=s[1], P_h=1, P_w=1)
    x, w, dy = _data(d, seed=7)
    calls = []
    real = tg.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(tg.pl, "pallas_call", counting)
    di = ops.conv2d_input_grad(dy, w, d)
    assert len(calls) == 1, f"s={s}: {len(calls)} dispatches"
    di_want, _ = _materialized_oracle(x, w, dy, d)
    np.testing.assert_allclose(di, di_want, rtol=5e-4, atol=5e-4)


def test_auto_keeps_asym_and_dilated_specs_on_pallas(rng):
    """Dispatch-events acceptance: ``"auto"`` routes asymmetric-stride and
    dilated specs to the pallas engine for every pass -- no bp_phase
    capability fallback."""
    x = jnp.asarray(rng.randn(2, 3, 12, 12), jnp.float32)
    w = jnp.asarray(rng.randn(4, 3, 3, 3) * 0.5, jnp.float32)
    for spec in (ConvSpec.make(stride=(1, 2), padding=1),
                 ConvSpec.make(stride=(3, 2), padding=1),
                 ConvSpec.make(stride=2, padding=2, dilation=2),
                 ConvSpec.make(stride=(2, 1), padding=(2, 1),
                               dilation=(2, 1))):
        reset_dispatch_events()
        jax.grad(lambda a, b: conv2d(a, b, spec, "auto").sum(),
                 argnums=(0, 1))(x, w)
        ev = dispatch_events()
        for pass_name in ("forward", "input_grad", "weight_grad"):
            assert ev.get(f"{pass_name}:pallas", 0) >= 1, (spec, ev)
            assert not any(k.startswith(f"{pass_name}:")
                           and k != f"{pass_name}:pallas" for k in ev), (
                spec, ev)


# ---------------------------------------------------------------------------
# Hypothesis sweep: the full (s_h != s_w) x (d_h, d_w > 1) grid
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(
    hi=st.integers(6, 13), wi=st.integers(6, 13),
    k_h=st.integers(1, 3), k_w=st.integers(1, 3),
    s_h=st.integers(1, 3), s_w=st.integers(1, 3),
    d_h=st.integers(1, 3), d_w=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_property_per_axis_pallas_grads(hi, wi, k_h, k_w, s_h, s_w,
                                        d_h, d_w, seed):
    """Property: over the (s_h != s_w) x (d_h, d_w > 1) grid, the
    end-to-end Pallas policy gradients equal the kernel-materialization
    oracle (``bp_phase``, which the dispatcher feeds the zero-dilated
    kernel -- the pre-PR-4 lowering kept exactly for this cross-check).

    The oracle, not ``jax.grad`` of the lax engine, is the ground truth
    here on purpose: XLA's own conv-transpose autodiff hard-crashes
    (algebraic_simplifier assertion) on some strided+dilated remainder
    geometries in this grid, e.g. H=10/K=2/s_h=3/d_h=3.  The oracle is
    itself anchored against lax on the deterministic grid above."""
    if s_h == s_w and d_h == 1 and d_w == 1:
        return                       # square dense: covered elsewhere
    keff_h, keff_w = (k_h - 1) * d_h + 1, (k_w - 1) * d_w + 1
    p_h, p_w = min(1, keff_h - 1), min(1, keff_w - 1)
    if hi + 2 * p_h < keff_h or wi + 2 * p_w < keff_w:
        return
    spec = ConvSpec.make(stride=(s_h, s_w), dilation=(d_h, d_w),
                         padding=(p_h, p_w))
    d = spec_dims((2, 2, hi, wi), (3, 2, k_h, k_w), spec)
    if d.H_o < 1 or d.W_o < 1:
        return
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(2, 2, hi, wi), jnp.float32)
    w = jnp.asarray(r.randn(3, 2, k_h, k_w) * 0.5, jnp.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(jnp.sin(conv2d(a, b, spec, pol)))
    oracle = jax.grad(loss("bp_phase"), argnums=(0, 1))(x, w)
    got = jax.grad(loss("pallas"), argnums=(0, 1))(x, w)
    # Forward IS safe to anchor on lax (no conv-transpose involved).
    np.testing.assert_allclose(
        conv2d(x, w, spec, "pallas"), conv2d(x, w, spec, "lax"),
        rtol=5e-3, atol=5e-3, err_msg=f"fwd {spec}")
    for o, g, name in zip(oracle, got, ("dI", "dW")):
        np.testing.assert_allclose(g, o, rtol=5e-3, atol=5e-3,
                                   err_msg=f"pallas vs oracle {name} {spec}")
