"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + no NaNs, plus decode-vs-forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_config, get_smoke_config
from repro.configs.base import applicable_shapes
from repro.models import build_model
from repro.optim import adamw
from repro.train import train_step as TS


def _smoke_batch(cfg, rng, B=2, L=32):
    batch = {}
    if cfg.family == "audio":
        batch["frontend"] = jnp.asarray(
            rng.standard_normal((B, L, cfg.d_frontend)), jnp.float32)
        batch["targets"] = jnp.zeros((B, L), jnp.int32)
    else:
        lt = L - (cfg.frontend_tokens if cfg.family == "vlm" else 0)
        batch["tokens"] = jnp.ones((B, lt), jnp.int32)
        batch["targets"] = jnp.zeros((B, lt), jnp.int32)
        if cfg.family == "vlm":
            batch["frontend"] = jnp.asarray(
                rng.standard_normal((B, cfg.frontend_tokens, cfg.d_frontend)),
                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        B, L = 2, 32
        batch = _smoke_batch(cfg, rng, B, L)
        logits, aux = m.forward(params, batch)
        lpred = batch["targets"].shape[1]
        assert logits.shape == (B, lpred, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_one_train_step(self, arch):
        cfg = get_smoke_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        step_fn = TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                                     total_steps=10, warmup=1)
        rng = np.random.default_rng(1)
        batch = _smoke_batch(cfg, rng)
        params2, opt2, metrics = jax.jit(step_fn)(params, opt, batch,
                                                  jnp.int32(0))
        assert np.isfinite(float(metrics["loss"]))
        assert np.isfinite(float(metrics["grad_norm"]))
        # params actually changed
        delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
            jax.tree.leaves(params), jax.tree.leaves(params2)))
        assert delta > 0

    def test_full_config_exact_dims(self, arch):
        """The FULL config carries the exact published dims (never built on
        CPU, only eval_shape'd by the dry-run)."""
        cfg = get_config(arch)
        assert cfg.n_layers >= 32
        assert cfg.vocab > 500
        shapes = applicable_shapes(cfg)
        assert "train_4k" in shapes and "prefill_32k" in shapes
        if cfg.is_encoder_only:
            assert "decode_32k" not in shapes
        if not cfg.supports_long_context:
            assert "long_500k" not in shapes


@pytest.mark.parametrize("arch", ["smollm_360m", "mamba2_370m",
                                  "recurrentgemma_9b", "deepseek_v3_671b",
                                  "moonshot_v1_16b_a3b", "granite_3_8b"])
def test_decode_matches_forward(arch):
    """Token-by-token decode reproduces the full-sequence forward logits."""
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(42))
    B, L = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, L), 0, cfg.vocab)
    logits_full, _ = m.forward(params, {"tokens": toks, "targets": toks})
    cache = m.init_cache(B, L)
    outs = []
    for t in range(L):
        lg, cache = m.decode_step(params, cache, toks[:, t], jnp.int32(t))
        outs.append(lg)
    logits_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_full, np.float32),
                               np.asarray(logits_dec, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_blockwise_attention_matches_dense():
    """The flash-style blockwise SDPA must equal dense attention."""
    from repro.models import attention as A
    r = np.random.RandomState(0)
    q = jnp.asarray(r.randn(2, 64, 4, 16), jnp.float32)
    k = jnp.asarray(r.randn(2, 64, 2, 16), jnp.float32)
    v = jnp.asarray(r.randn(2, 64, 2, 16), jnp.float32)
    for causal in (True, False):
        for window in (None, 16):
            dense = A._sdpa_dense(q, k, v, causal=causal, window=window,
                                  q_offset=0, kv_len=None, scale=0.25)
            blk = A._sdpa_blockwise(q, k, v, causal=causal, window=window,
                                    q_offset=0, kv_len=None, scale=0.25)
            np.testing.assert_allclose(dense, blk, rtol=2e-4, atol=2e-4,
                                       err_msg=f"causal={causal} w={window}")


def test_mamba2_chunked_matches_naive_scan():
    """Chunked SSD == naive O(L) recurrence."""
    from repro.models import mamba2 as M2
    cfg = get_smoke_config("mamba2_370m")
    b, l, h, p, s = 1, 256, 2, 8, 4
    r = np.random.RandomState(0)
    xh = jnp.asarray(r.randn(b, l, h, p), jnp.float32)
    dt = jnp.asarray(np.abs(r.randn(b, l, h)) * 0.1, jnp.float32)
    a_log = jnp.asarray(r.randn(h) * 0.1, jnp.float32)
    B = jnp.asarray(r.randn(b, l, s), jnp.float32)
    C = jnp.asarray(r.randn(b, l, s), jnp.float32)
    got = M2._ssd_chunked(xh, dt, a_log, B, C)
    # naive recurrence
    a = np.exp(np.asarray(dt) * (-np.exp(np.asarray(a_log)))[None, None])
    state = np.zeros((b, h, p, s))
    ys = []
    for t in range(l):
        upd = np.einsum("bh,bhp,bs->bhps", np.asarray(dt)[:, t], np.asarray(xh)[:, t],
                        np.asarray(B)[:, t])
        state = state * a[:, t][:, :, None, None] + upd
        ys.append(np.einsum("bhps,bs->bhp", state, np.asarray(C)[:, t]))
    want = np.stack(ys, axis=1)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_window_skip_attention_matches_dense():
    """Perf-iteration path: O(L*W) local-window schedule == dense attention."""
    from repro.models import attention as A
    r = np.random.RandomState(3)
    for (l, w, hk, g) in [(128, 16, 2, 2), (96, 32, 1, 4), (100, 16, 1, 1)]:
        h = hk * g
        q = jnp.asarray(r.randn(2, l, h, 8), jnp.float32)
        k = jnp.asarray(r.randn(2, l, hk, 8), jnp.float32)
        v = jnp.asarray(r.randn(2, l, hk, 8), jnp.float32)
        want = A._sdpa_dense(q, k, v, causal=True, window=w, q_offset=0,
                             kv_len=None, scale=0.35)
        got = A._sdpa_local_window(q, k, v, window=w, scale=0.35)
        np.testing.assert_allclose(want, got, rtol=2e-4, atol=2e-4)


def test_autoencoder_trains_through_make_train_step():
    """The conv -> conv_transpose autoencoder (PR 5): forward shapes, the
    decoder's transposed convs dispatching through the engines (``*_T``
    events), and a few REAL ``make_train_step`` steps (the ``loss=``
    plugin) reducing the reconstruction MSE under a mixed policy."""
    from repro.core import dispatch_events, reset_dispatch_events
    from repro.models import model as M

    cfg = M.AutoencoderConfig(c_in=2, widths=(4, 8), k=3,
                              conv_policy="auto")
    params = M.init_autoencoder(jax.random.PRNGKey(0), cfg)
    # Smooth low-frequency images (a learnable reconstruction target).
    r = np.random.RandomState(0)
    yy, xx = np.meshgrid(np.arange(8), np.arange(8), indexing="ij")
    imgs = np.stack([np.sin(2 * np.pi * f * yy / 8 + p)
                     * np.cos(2 * np.pi * g * xx / 8 + q)
                     for f, g, p, q in
                     [(1, 1, 0.3, 0.1), (1, 2, 1.0, 0.5),
                      (2, 1, 0.0, 2.0), (1, 1, 2.0, 1.2)]])
    x = jnp.asarray(imgs.reshape(2, 2, 8, 8), jnp.float32)
    reset_dispatch_events()
    y = M.autoencoder_apply(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    ev = dispatch_events()
    assert sum(v for k, v in ev.items() if k.startswith("forward_T:")) == 2

    step_fn = jax.jit(TS.make_train_step(
        cfg, adamw.AdamWConfig(peak_lr=2e-2, weight_decay=0.0),
        total_steps=60, warmup=1, loss=M.autoencoder_loss,
        conv_policy="fwd=pallas,dgrad=bp_phase,wgrad=bp_im2col"))
    opt = adamw.init_state(params)
    batch = {"image": x}
    first = last = None
    for step in range(60):
        params, opt, metrics = step_fn(params, opt, batch, jnp.int32(step))
        last = float(metrics["mse"])
        first = last if first is None else first
    assert np.isfinite(last)
    assert last < first * 0.5, (first, last)
