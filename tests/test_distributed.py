"""Distributed runtime tests.

The dry-run proves lowering/compilation on the production meshes; these
tests prove the sharded step EXECUTES correctly by running it on 8 virtual
CPU devices in a subprocess (the flag must be set before jax initializes,
hence the isolation), and that checkpoints restore elastically onto a
different sharding than they were saved from.
"""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, os.path.join(%(root)r, "src"))
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.dist import sharding as SH
    from repro.dist.constraints import set_activation_policy
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train import train_step as TS

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = get_smoke_config("smollm_360m")
    model = M.build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    set_activation_policy(("data",))

    p_sh = SH.to_shardings(SH.param_specs(params, mesh), mesh)
    o_sh = SH.to_shardings(SH.opt_state_specs(params, mesh), mesh)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    b_sh = SH.to_shardings(SH.batch_specs(batch, mesh), mesh)

    with mesh:
        params_d = jax.device_put(params, p_sh)
        opt_d = jax.device_put(opt, o_sh)
        batch_d = jax.device_put(batch, b_sh)
        step = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                                          total_steps=10, warmup=1),
                       in_shardings=(p_sh, o_sh, b_sh, None),
                       out_shardings=(p_sh, o_sh, None))
        losses = []
        p, o = params_d, opt_d
        for s in range(3):
            p, o, m = step(p, o, batch_d, jnp.int32(s))
            losses.append(float(m["loss"]))

    # single-device reference: identical math
    step1 = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                                       total_steps=10, warmup=1))
    p1, o1 = params, opt
    ref = []
    for s in range(3):
        p1, o1, m1 = step1(p1, o1, batch, jnp.int32(s))
        ref.append(float(m1["loss"]))
    print(json.dumps({"sharded": losses, "single": ref}))
""")


_CONV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, os.path.join(%(root)r, "src"))
    import jax, jax.numpy as jnp
    from repro.core import conv as C
    from repro.dist import sharding as SH
    from repro.dist.constraints import set_activation_policy
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train import train_step as TS

    policy = %(policy)r
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = M.AutoencoderConfig(c_in=3, widths=(16, 32), k=3,
                              conv_policy="lax")
    params = M.init_autoencoder(jax.random.PRNGKey(0), cfg)
    opt = adamw.init_state(params)
    set_activation_policy(SH.batch_axes(mesh, policy))

    p_sh = SH.to_shardings(SH.param_specs(params, mesh, policy), mesh)
    o_sh = SH.to_shardings(SH.opt_state_specs(params, mesh, policy), mesh)
    batch = {"image": jax.random.normal(jax.random.PRNGKey(1),
                                        (8, 3, 16, 16), jnp.float32)}
    b_sh = SH.to_shardings(SH.batch_specs(batch, mesh, policy), mesh)

    C.reset_dispatch_events()
    with mesh:
        p = jax.device_put(params, p_sh)
        o = jax.device_put(opt, o_sh)
        bd = jax.device_put(batch, b_sh)
        step = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(
                                              peak_lr=1e-3),
                                          total_steps=10, warmup=1,
                                          loss=M.autoencoder_loss,
                                          conv_mesh=policy),
                       in_shardings=(p_sh, o_sh, b_sh, None),
                       out_shardings=(p_sh, o_sh, None))
        losses = []
        for s in range(3):
            p, o, m = step(p, o, bd, jnp.int32(s))
            losses.append(float(m["loss"]))
    mesh_events = {k: v for k, v in C.dispatch_events().items()
                   if k.startswith("mesh")}

    # single-device reference: identical math, no mesh
    step1 = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                                       total_steps=10, warmup=1,
                                       loss=M.autoencoder_loss))
    p1, o1, ref = params, opt, []
    for s in range(3):
        p1, o1, m1 = step1(p1, o1, batch, jnp.int32(s))
        ref.append(float(m1["loss"]))
    print(json.dumps({"sharded": losses, "single": ref,
                      "mesh_events": mesh_events}))
""")


@pytest.mark.slow
def test_sharded_train_step_executes_and_matches_single_device():
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT % {"root": ROOT}],
        capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["sharded"], res["single"],
                               rtol=1e-4, atol=1e-5)


@pytest.mark.slow
@pytest.mark.dist
@pytest.mark.parametrize("policy", ["tp", "dp_only"])
def test_conv_autoencoder_sharded_training_matches_replicated(policy):
    """The autoencoder's convs train through conv_parallel's shard_map
    lowerings (params + batch sharded end-to-end on a 4x2 mesh) and the
    loss curve matches the single-device step; the dispatch events prove
    the sharded path actually ran -- and that the one layer "tp" cannot
    channel-shard (decoder output, Cout=3) degraded with a reason instead
    of crashing."""
    out = subprocess.run(
        [sys.executable, "-c",
         _CONV_SCRIPT % {"root": ROOT, "policy": policy}],
        capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["sharded"], res["single"],
                               rtol=1e-4, atol=1e-5)
    ev = res["mesh_events"]
    assert any(k.startswith("mesh:conv2d:") for k in ev), ev
    assert any(k.startswith("mesh:conv2d_T:") for k in ev), ev
    if policy == "tp":
        # final decoder layer: Cout=3 % model=2 -- dropped, not crashed
        assert ev.get("mesh:drop:cout"), ev


def test_elastic_checkpoint_restore_onto_new_sharding(tmp_path):
    """Save unsharded, restore with an explicit sharding tree (the elastic
    resume path used after a mesh-shape change)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.ckpt import checkpoint as CKPT

    tree = {"w": np.arange(64, dtype=np.float32).reshape(8, 8),
            "b": np.ones(8, np.float32)}
    CKPT.save(str(tmp_path), 5, tree)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None)),
                 "b": NamedSharding(mesh, P())}
    step, restored = CKPT.restore(str(tmp_path), shardings=shardings)
    assert step == 5
    assert restored["w"].sharding.is_equivalent_to(shardings["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


def test_multipod_mesh_shape():
    """make_production_mesh contract (function, not module constant)."""
    import inspect
    from repro.launch import mesh as mesh_mod
    assert inspect.isfunction(mesh_mod.make_production_mesh)
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
