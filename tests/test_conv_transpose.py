"""First-class transposed convolution (``ConvTransposeSpec`` +
``conv2d_transpose``): tap-native lhs dilation through the engines.

PR 5's tentpole invariants:
  * the transposed forward role-swaps onto the engines' input-grad
    machinery over the MIRROR regular conv (``transpose_dims``) -- on
    ``pallas`` that is ONE fused ``tap_gemm_phased`` launch over the
    ``s_h*s_w`` phase grid, zero insertion skipped at plan time;
  * engines WITHOUT the ``native_transpose`` capability get the physical
    zero-insertion materialization lowering
    (``conv2d_transpose_materialized``), which doubles as the executable
    oracle every implicit path is tested against;
  * the VJP lowers to the already-tested regular-conv engines: dX is the
    mirror strided conv, dW the mirror weight grad with roles swapped;
  * ``"auto"`` keeps plannable transposed specs on ``pallas`` (asserted
    via the ``*_T`` dispatch events).
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ConvTransposeSpec, conv2d_transpose,
                        conv2d_transpose_materialized, conv_policy,
                        conv_transpose_output_shape, dispatch_events,
                        policy_report, reset_dispatch_events,
                        transpose_dims, transpose_tap_counts)
from repro.core.conv import ENGINES
from repro.kernels import tap_gemm as tg

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _data(x_shape, w_shape, seed=0):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(*x_shape), jnp.float32)
    w = jnp.asarray(r.randn(*w_shape) * 0.5, jnp.float32)
    return x, w


def _lax_transpose_anchor(x, w, spec: ConvTransposeSpec):
    """XLA's native transposed conv: lhs_dilation on conv_general_dilated
    (defined for every geometry we accept -- the anchor where XLA supports
    it; the materialization oracle covers the rest)."""
    g = spec.groups
    cin, cog, kh, kw = w.shape
    keff_h, keff_w = spec.effective_kernel(kh, kw)
    (ph_lo, ph_hi), (pw_lo, pw_hi) = spec.padding
    wt = w[..., ::-1, ::-1].reshape(g, cin // g, cog, kh, kw)
    wt = wt.transpose(0, 2, 1, 3, 4).reshape(g * cog, cin // g, kh, kw)
    return jax.lax.conv_general_dilated(
        x, wt, (1, 1),
        [(keff_h - 1 - ph_lo, keff_h - 1 - ph_hi + spec.op_h),
         (keff_w - 1 - pw_lo, keff_w - 1 - pw_hi + spec.op_w)],
        lhs_dilation=spec.stride, rhs_dilation=spec.dilation,
        dimension_numbers=("NCHW", "OIHW", "NCHW"), feature_group_count=g)


# ---------------------------------------------------------------------------
# Spec validation and shape inference
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError):
        ConvTransposeSpec.make(stride=2, output_padding=2)   # op >= s
    with pytest.raises(ValueError):
        ConvTransposeSpec.make(stride=1, output_padding=1)
    with pytest.raises(ValueError):
        ConvTransposeSpec.make(stride=0)
    with pytest.raises(ValueError):
        ConvTransposeSpec(layout="NHCW")
    s = ConvTransposeSpec.make(stride=(2, 3), padding=(1, 2),
                               output_padding=(1, 2), dilation=2)
    assert (s.s_h, s.s_w, s.op_h, s.op_w, s.d_h, s.d_w) == (2, 3, 1, 2, 2, 2)
    assert ConvTransposeSpec.coerce(None) == ConvTransposeSpec()
    assert ConvTransposeSpec.coerce({"stride": 2, "output_padding": 1}) == \
        ConvTransposeSpec.make(stride=2, output_padding=1)


def test_output_shape_formula():
    # The PyTorch ConvTranspose2d formula, checked against the real output.
    spec = ConvTransposeSpec.make(stride=(2, 3), padding=(1, 0),
                                  output_padding=(1, 2), dilation=(2, 1))
    x, w = _data((2, 4, 7, 5), (4, 6, 3, 3))
    want = conv_transpose_output_shape(x.shape, w.shape, spec)
    y = conv2d_transpose(x, w, spec, "lax")
    assert y.shape == want
    h, wd = y.shape[2:]
    assert h == (7 - 1) * 2 + (3 - 1) * 2 + 1 - 2 * 1 + 1
    assert wd == (5 - 1) * 3 + 3 - 0 + 2


def test_mirror_dims_roundtrip():
    """transpose_dims builds the mirror conv whose output IS the transposed
    input, with output_padding landing on the tiling remainder R."""
    spec = ConvTransposeSpec.make(stride=(2, 3), padding=1,
                                  output_padding=(1, 2))
    d = transpose_dims((2, 6, 8, 5), (6, 4, 3, 3), spec)
    assert (d.H_o, d.W_o) == (8, 5)
    assert (d.R_h, d.R_w) == (1, 2)
    assert (d.N, d.C) == (6, 4)


# ---------------------------------------------------------------------------
# Forward + VJP equivalence vs the materialization oracle and vs lax
# ---------------------------------------------------------------------------

GRID = [
    # (x_shape, w_shape, spec): stride-2 decoder, asym stride, dilated
    # kernel, grouped, output_padding variants, stride 1, fat padding.
    ((2, 8, 8, 8), (8, 4, 3, 3),
     ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)),
    ((1, 4, 7, 5), (4, 6, 3, 3),
     ConvTransposeSpec.make(stride=(2, 3), padding=1,
                            output_padding=(1, 2))),
    ((2, 4, 6, 6), (4, 4, 3, 3),
     ConvTransposeSpec.make(stride=2, padding=2, output_padding=1,
                            dilation=2)),
    ((2, 4, 6, 6), (4, 2, 2, 2),
     ConvTransposeSpec.make(stride=2, groups=2)),
    ((1, 3, 9, 9), (3, 5, 3, 3), ConvTransposeSpec.make(stride=1,
                                                        padding=1)),
    ((1, 2, 6, 6), (2, 3, 5, 3),
     ConvTransposeSpec.make(stride=(3, 2), padding=(2, 1),
                            output_padding=(2, 0), dilation=(1, 2))),
]

POLICIES = ("pallas", "bp_phase", "bp_im2col", "traditional", "lax", "auto",
            "fwd=pallas,dgrad=bp_phase,wgrad=bp_im2col")


@pytest.mark.parametrize(
    "x_shape,w_shape,spec", GRID,
    ids=lambda v: str(v) if isinstance(v, tuple) else
    f"s{v.s_h}x{v.s_w}_d{v.d_h}x{v.d_w}_op{v.op_h}{v.op_w}_g{v.groups}")
def test_forward_and_grads_match_oracle(x_shape, w_shape, spec):
    """Every engine (and the auto / mixed policies) reproduces the
    zero-insertion materialization oracle, forward and VJP, and the oracle
    itself is anchored on XLA's native lhs-dilated conv."""
    x, w = _data(x_shape, w_shape)
    want = conv2d_transpose_materialized(x, w, spec, "lax")
    np.testing.assert_allclose(want, _lax_transpose_anchor(x, w, spec),
                               rtol=1e-4, atol=1e-4)

    def oracle_loss(a, b):
        return jnp.sum(jnp.sin(conv2d_transpose_materialized(a, b, spec,
                                                             "lax")))
    ox, ow = jax.grad(oracle_loss, argnums=(0, 1))(x, w)
    for pol in POLICIES:
        y = conv2d_transpose(x, w, spec, pol)
        assert y.shape == want.shape
        np.testing.assert_allclose(y, want, rtol=5e-4, atol=5e-4,
                                   err_msg=f"fwd {pol}")
        gx, gw = jax.grad(
            lambda a, b: jnp.sum(jnp.sin(conv2d_transpose(a, b, spec, pol))),
            argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, ox, rtol=5e-4, atol=5e-4,
                                   err_msg=f"dX {pol}")
        np.testing.assert_allclose(gw, ow, rtol=5e-3, atol=5e-3,
                                   err_msg=f"dW {pol}")


def test_kwargs_surface_and_jit_vmap():
    x, w = _data((2, 4, 6, 6), (4, 3, 3, 3))
    spec = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)
    want = conv2d_transpose(x, w, spec, "bp_phase")
    got = conv2d_transpose(x, w, stride=2, padding=1, output_padding=1,
                           policy="bp_phase")
    np.testing.assert_array_equal(want, got)
    jitted = jax.jit(lambda a, b: conv2d_transpose(a, b, spec, "pallas"))
    np.testing.assert_allclose(jitted(x, w), want, rtol=5e-4, atol=5e-4)
    batched = jax.vmap(lambda a: conv2d_transpose(a[None], w, spec,
                                                  "bp_phase")[0])(x)
    np.testing.assert_allclose(batched, want, rtol=5e-4, atol=5e-4)
    with pytest.raises(TypeError):
        conv2d_transpose(x, w, spec, stride=2)         # geometry twice
    with pytest.raises(TypeError):
        conv2d_transpose(x, w, spec, "pallas", policy="lax")


def test_nhwc_layout():
    spec = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1,
                                  layout="NHWC")
    x, w = _data((2, 4, 6, 6), (4, 3, 3, 3))
    want = conv2d_transpose(x, w, spec.with_layout("NCHW"), "bp_phase")
    xt = x.transpose(0, 2, 3, 1)
    got = conv2d_transpose(xt, w, spec, "bp_phase")
    np.testing.assert_allclose(got.transpose(0, 3, 1, 2), want,
                               rtol=1e-5, atol=1e-5)
    # Shape inference follows the spec's layout.
    assert got.shape == conv_transpose_output_shape(xt.shape, w.shape, spec)
    assert want.shape == conv_transpose_output_shape(
        x.shape, w.shape, spec.with_layout("NCHW"))


# ---------------------------------------------------------------------------
# Dispatch: auto routing, capability flag, fused launch, introspection
# ---------------------------------------------------------------------------

def test_native_transpose_flags():
    assert ENGINES["pallas"].native_transpose
    assert ENGINES["bp_phase"].native_transpose
    assert ENGINES["bp_im2col"].native_transpose
    assert ENGINES["lax"].native_transpose
    assert not ENGINES["traditional"].native_transpose


def test_auto_keeps_transposed_specs_on_pallas():
    """Dispatch-events acceptance: ``"auto"`` routes a plannable transposed
    spec to the pallas engine for every pass, under the ``*_T`` keys."""
    for x_shape, w_shape, spec in (
            ((2, 8, 8, 8), (8, 4, 3, 3),
             ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)),
            ((1, 4, 7, 5), (4, 6, 3, 3),
             ConvTransposeSpec.make(stride=(2, 3), padding=1)),
            ((2, 4, 6, 6), (4, 4, 3, 3),
             ConvTransposeSpec.make(stride=2, padding=2, output_padding=1,
                                    dilation=2))):
        x, w = _data(x_shape, w_shape)
        reset_dispatch_events()
        jax.grad(lambda a, b: conv2d_transpose(a, b, spec, "auto").sum(),
                 argnums=(0, 1))(x, w)
        ev = dispatch_events()
        for pass_name in ("forward_T", "input_grad_T", "weight_grad_T"):
            assert ev.get(f"{pass_name}:pallas", 0) >= 1, (spec, ev)
            assert not any(k.startswith(f"{pass_name}:")
                           and k != f"{pass_name}:pallas" for k in ev), (
                spec, ev)


def test_auto_stride1_transposed_stays_dense():
    """Stride-1 transposed conv has no zero-space: auto resolves bp_phase."""
    spec = ConvTransposeSpec.make(stride=1, padding=1)
    x, w = _data((1, 3, 8, 8), (3, 4, 3, 3))
    reset_dispatch_events()
    conv2d_transpose(x, w, spec, "auto")
    assert dispatch_events().get("forward_T:bp_phase", 0) >= 1


def test_transposed_forward_is_one_fused_launch(monkeypatch):
    """The pallas transposed forward is ONE tap_gemm_phased dispatch for
    all s_h*s_w output phases."""
    spec = ConvTransposeSpec.make(stride=(2, 3), padding=1,
                                  output_padding=(1, 2))
    x, w = _data((1, 4, 6, 6), (4, 5, 3, 3))
    calls = []
    real = tg.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(tg.pl, "pallas_call", counting)
    y = conv2d_transpose(x, w, spec, "pallas")
    assert len(calls) == 1, f"{len(calls)} dispatches"
    np.testing.assert_allclose(
        y, conv2d_transpose_materialized(x, w, spec, "lax"),
        rtol=5e-4, atol=5e-4)


def test_conv_policy_context_covers_transpose():
    spec = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)
    x, w = _data((1, 4, 6, 6), (4, 3, 3, 3))
    reset_dispatch_events()
    with conv_policy("traditional"):
        y = conv2d_transpose(x, w, spec, "pallas")   # context beats per-call
    assert dispatch_events().get("forward_T:traditional", 0) == 1
    np.testing.assert_allclose(
        y, conv2d_transpose_materialized(x, w, spec, "lax"),
        rtol=5e-4, atol=5e-4)


def test_tap_counts_skip_ratio():
    """Zero-insertion accounting: the fused plan runs the compact taps,
    the materialization would run s_h*s_w*K_eff_h*K_eff_w -- skip_ratio is
    1 - 1/(s_h*s_w) dense, and folds in kernel-dilation skipping."""
    d = transpose_dims((2, 8, 8, 8), (8, 4, 3, 3),
                       ConvTransposeSpec.make(stride=2, padding=1,
                                              output_padding=1))
    taps = transpose_tap_counts(d)
    assert taps == {"real": 9, "zero_inserted": 36, "skip_ratio": 0.75}
    d2 = transpose_dims((2, 4, 6, 6), (4, 4, 3, 3),
                        ConvTransposeSpec.make(stride=2, padding=2,
                                               output_padding=1, dilation=2))
    taps2 = transpose_tap_counts(d2)
    assert taps2["real"] == 9 and taps2["zero_inserted"] == 100
    assert taps2["real"] < taps2["zero_inserted"]
    assert taps2["skip_ratio"] == 0.91    # 1 - 9/(4*25)


def test_policy_report_transposed():
    spec = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)
    rep = policy_report((2, 8, 16, 16), (8, 4, 3, 3), spec, "auto")
    assert rep["transpose"] and rep["pallas_path"]
    assert set(rep["passes"]) == {"forward", "input_grad", "weight_grad"}
    assert all(v["engine"] == "pallas" for v in rep["passes"].values())
    assert rep["taps"]["real"] < rep["taps"]["zero_inserted"]
    assert rep["plan"]["pallas_path"]
    # Regular specs keep reporting (and now carry the transpose flag).
    rep2 = policy_report((2, 8, 16, 16), (4, 8, 3, 3))
    assert rep2["transpose"] is False


def test_oversized_padding_falls_back_recorded():
    """padding > K_eff-1 is outside the paper constraints: implicit engines
    fall back to lax -- recorded, never silent, and still exact."""
    spec = ConvTransposeSpec.make(stride=2, padding=(3, 3))   # K=3, p=3
    x, w = _data((1, 3, 8, 8), (3, 4, 3, 3))
    reset_dispatch_events()
    y = conv2d_transpose(x, w, spec, "auto")
    ev = dispatch_events()
    assert ev.get("forward_T:lax", 0) >= 1, ev
    np.testing.assert_allclose(
        y, conv2d_transpose_materialized(x, w, spec, "lax"),
        rtol=5e-4, atol=5e-4)


# ---------------------------------------------------------------------------
# Grep-lint: no hand-rolled zero-insertion upsampling outside core/
# ---------------------------------------------------------------------------

def test_zero_insert_lint_repo_clean():
    from scripts import check_no_zero_insert as lint
    assert lint.main([str(ROOT / "scripts" / "check_no_zero_insert.py"),
                      str(ROOT)]) == 0


def test_zero_insert_lint_catches_strided_scatter(tmp_path):
    from scripts import check_no_zero_insert as lint
    bad = tmp_path / "src" / "repro" / "models" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(
        "up = jnp.zeros((B, C, 2*H, 2*W))\n"
        "up = up.at[..., ::2, ::2].set(x)\n")
    assert lint.scan(tmp_path), "strided-scatter upsampling not caught"
    assert lint.main(["check", str(tmp_path)]) == 1
    # core/ keeps the privilege: same idiom under core/ passes.
    ok = tmp_path / "src" / "repro" / "core" / "impl.py"
    ok.parent.mkdir(parents=True)
    bad.unlink()
    ok.write_text("out = out.at[..., ::s, ::s].set(x)\n")
    assert lint.main(["check", str(tmp_path)]) == 0


# ---------------------------------------------------------------------------
# Hypothesis sweep: (s_h != s_w) x dilation x output_padding
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(
    hi=st.integers(3, 9), wi=st.integers(3, 9),
    k_h=st.integers(1, 3), k_w=st.integers(1, 3),
    s_h=st.integers(1, 3), s_w=st.integers(1, 3),
    d_h=st.integers(1, 2), d_w=st.integers(1, 2),
    op_h=st.integers(0, 2), op_w=st.integers(0, 2),
    seed=st.integers(0, 2**16),
)
def test_property_transposed_grads(hi, wi, k_h, k_w, s_h, s_w, d_h, d_w,
                                   op_h, op_w, seed):
    """Property: over (s_h != s_w) x (d_h, d_w) x output_padding, the
    end-to-end pallas/auto transposed conv equals the zero-insertion
    materialization oracle, forward and VJP (fp32 tolerance).

    The oracle (not XLA's transposed autodiff) is the ground truth: XLA's
    conv-transpose gradient aborts on some strided+dilated remainder
    geometries, the same reason the PR-4 sweep anchors on the oracle."""
    op_h, op_w = min(op_h, s_h - 1), min(op_w, s_w - 1)
    keff_h, keff_w = (k_h - 1) * d_h + 1, (k_w - 1) * d_w + 1
    p_h, p_w = min(1, keff_h - 1), min(1, keff_w - 1)
    spec = ConvTransposeSpec.make(stride=(s_h, s_w), dilation=(d_h, d_w),
                                  padding=(p_h, p_w),
                                  output_padding=(op_h, op_w))
    h_out, w_out = spec.output_shape(hi, wi, k_h, k_w)
    if h_out < 1 or w_out < 1:
        return
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(2, 2, hi, wi), jnp.float32)
    w = jnp.asarray(r.randn(2, 3, k_h, k_w) * 0.5, jnp.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(jnp.sin(conv2d_transpose(a, b, spec,
                                                             pol)))
    want = conv2d_transpose_materialized(x, w, spec, "lax")
    ox, ow = jax.grad(
        lambda a, b: jnp.sum(jnp.sin(conv2d_transpose_materialized(
            a, b, spec, "lax"))), argnums=(0, 1))(x, w)
    for pol in ("pallas", "auto"):
        np.testing.assert_allclose(conv2d_transpose(x, w, spec, pol), want,
                                   rtol=5e-3, atol=5e-3,
                                   err_msg=f"fwd {pol} {spec}")
        gx, gw = jax.grad(loss(pol), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx, ox, rtol=5e-3, atol=5e-3,
                                   err_msg=f"dX {pol} {spec}")
        np.testing.assert_allclose(gw, ow, rtol=5e-3, atol=5e-3,
                                   err_msg=f"dW {pol} {spec}")
