"""Substrate tests: data pipeline, optimizer, schedules, compression,
checkpointing, fault tolerance, sharding rules."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, make_batch
from repro.ckpt import checkpoint as CKPT
from repro.ft import failures as FT
from repro.optim import adamw, schedule, compression


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

class TestData:
    def setup_method(self):
        self.cfg = get_smoke_config("smollm_360m")
        self.dcfg = DataConfig(seed=7, seq_len=32, global_batch=8,
                               vocab=self.cfg.vocab)

    def test_deterministic_per_step(self):
        b1 = make_batch(self.cfg, self.dcfg, 5)
        b2 = make_batch(self.cfg, self.dcfg, 5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_different_steps_differ(self):
        b1 = make_batch(self.cfg, self.dcfg, 5)
        b2 = make_batch(self.cfg, self.dcfg, 6)
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_worker_sharding_partitions_batch(self):
        full = make_batch(self.cfg, self.dcfg, 3)
        got = [make_batch(self.cfg,
                          dataclasses.replace(self.dcfg, worker=w,
                                              n_workers=4), 3)
               for w in range(4)]
        assert all(g["tokens"].shape[0] == 2 for g in got)

    def test_restart_skip_ahead_exact(self):
        """Resume at step k yields exactly the batch a never-failed worker
        would have seen (no replay / no skip)."""
        want = make_batch(self.cfg, self.dcfg, 17)
        got = make_batch(self.cfg, self.dcfg, 17)  # fresh 'restarted' call
        np.testing.assert_array_equal(want["tokens"], got["tokens"])

    def test_vlm_audio_batches(self):
        vlm = get_smoke_config("internvl2_76b")
        b = make_batch(vlm, dataclasses.replace(self.dcfg, seq_len=32), 0)
        assert b["frontend"].shape[1] == vlm.frontend_tokens
        assert b["tokens"].shape[1] == 32 - vlm.frontend_tokens
        audio = get_smoke_config("hubert_xlarge")
        b = make_batch(audio, self.dcfg, 0)
        assert b["frontend"].shape == (8, 32, audio.d_frontend)


# ---------------------------------------------------------------------------
# Optimizer / schedules / compression
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_descends_quadratic(self):
        params = {"w": jnp.array([3.0, -2.0])}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(peak_lr=0.1, weight_decay=0.0)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = adamw.apply_updates(params, grads, state,
                                                   0.05, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clipping(self):
        params = {"w": jnp.zeros(3)}
        state = adamw.init_state(params)
        cfg = adamw.AdamWConfig(clip_norm=1.0, weight_decay=0.0)
        _, _, m = adamw.apply_updates(params, {"w": jnp.full(3, 1e6)},
                                      state, 0.1, cfg)
        assert float(m["grad_norm"]) > 1e5  # reported norm is pre-clip

    def test_wsd_shape(self):
        lr = [float(schedule.wsd(s, peak_lr=1.0, warmup=10, total=100))
              for s in range(100)]
        assert lr[5] < 1.0                     # warming up
        assert abs(lr[50] - 1.0) < 1e-6        # stable plateau
        assert lr[99] < 0.05                   # decayed
        assert abs(lr[89] - 1.0) < 1e-6        # plateau until 90%

    def test_int8_compression_error_feedback(self):
        r = np.random.RandomState(0)
        g = {"a": jnp.asarray(r.randn(64, 64), jnp.float32)}
        q, residual = compression.compress_tree_int8(g, jax.random.PRNGKey(0))
        deq = compression.decompress_tree_int8(q)
        err = np.abs(np.asarray(deq["a"] + residual["a"] - g["a"])).max()
        assert err < 1e-5                       # residual captures the error
        rel = (np.linalg.norm(np.asarray(deq["a"] - g["a"]))
               / np.linalg.norm(np.asarray(g["a"])))
        assert rel < 0.02                       # int8 quality

    def test_topk_sparsify_roundtrip(self):
        x = jnp.asarray(np.random.RandomState(1).randn(32, 32), jnp.float32)
        vals, idx, residual = compression.topk_sparsify(x, frac=0.1)
        dense = compression.topk_densify(vals, idx, x.shape)
        np.testing.assert_allclose(dense + residual, x, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
                "opt": {"step": np.int32(7)}}
        CKPT.save(str(tmp_path), 7, tree)
        step, got = CKPT.restore(str(tmp_path))
        assert step == 7
        np.testing.assert_array_equal(got["params"]["w"], tree["params"]["w"])

    def test_uncommitted_ignored(self, tmp_path):
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)})
        # fake a torn write: step_2 without COMMIT
        d = tmp_path / "step_00000002"
        d.mkdir()
        (d / "manifest.json").write_text("{}")
        step, _ = CKPT.restore(str(tmp_path))
        assert step == 1

    def test_corruption_detected(self, tmp_path):
        CKPT.save(str(tmp_path), 3, {"x": np.ones(8, np.float32)})
        target = tmp_path / "step_00000003" / "arr_00000.npy"
        arr = np.load(target)
        arr[0] = 999.0
        np.save(target, arr)
        with pytest.raises(IOError, match="corruption"):
            CKPT.restore(str(tmp_path))

    def test_rotation(self, tmp_path):
        for s in range(6):
            CKPT.save(str(tmp_path), s, {"x": np.ones(2)}, keep=3)
        assert CKPT.latest_steps(str(tmp_path)) == [3, 4, 5]

    def test_restore_given_step(self, tmp_path):
        for s in (1, 2):
            CKPT.save(str(tmp_path), s, {"x": np.full(2, float(s))})
        step, tree = CKPT.restore(str(tmp_path), step=1)
        assert step == 1 and tree["x"][0] == 1.0


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

class TestFT:
    def test_heartbeat_dead_detection(self):
        hb = FT.HeartbeatTable(4, timeout_s=10)
        for w in range(4):
            hb.beat(w, t=100.0)
        hb.beat(2, t=200.0)
        assert hb.dead(now=205.0) == [0, 1, 3]

    def test_straggler_eviction(self):
        sd = FT.StragglerDetector(4, threshold=1.5, patience=3)
        evicted = []
        for _ in range(5):
            evicted = sd.observe([1.0, 1.0, 1.0, 2.5])
        assert evicted == [3]

    def test_elastic_mesh_preserves_tp_divisibility(self):
        # 512 chips, model=16, 64 heads -> keep (32, 16)
        assert FT.elastic_mesh(512, 16, 64) == (32, 16)
        # lose some chips: 240 survivors
        d, m = FT.elastic_mesh(240, 16, 64)
        assert 64 % m == 0 and d * m <= 240 and d == 8
        # heads=15 forbids m=16 -> falls to 1
        d, m = FT.elastic_mesh(256, 16, 15)
        assert m == 1

    def test_restart_plan(self):
        hb = FT.HeartbeatTable(8, timeout_s=5)
        for w in range(8):
            hb.beat(w, t=0.0)
        hb.beat(3, t=-100.0)
        plan = FT.make_restart_plan(hb, [100, 200], 2, 16, now=6.0)
        assert plan is not None
        assert plan.resume_step == 200
        assert 3 in plan.failed_workers


# ---------------------------------------------------------------------------
# Sharding rules (mesh stub: rules only need axis sizes)
# ---------------------------------------------------------------------------

class _MeshStub:
    def __init__(self, **axes):
        self.shape = axes


class TestSharding:
    def test_param_specs_divisibility(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"blocks": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (32, 4096, 2048), jnp.bfloat16)}}}}
        spec = SH.param_specs(fake, mesh)["blocks"]["attn"]["wq"]["w"]
        assert spec == jax.sharding.PartitionSpec(None, "data", "model")

    def test_indivisible_falls_back(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"blocks": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (32, 963, 2048), jnp.bfloat16)}}}}  # 963 % 16 != 0
        spec = SH.param_specs(fake, mesh)["blocks"]["attn"]["wq"]["w"]
        assert spec == jax.sharding.PartitionSpec(None, None, "model")

    def test_moe_expert_parallel(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"blocks_moe": {"moe": {"wi": {"w": jax.ShapeDtypeStruct(
            (58, 256, 7168, 2048), jnp.bfloat16)}}}}
        spec = SH.param_specs(fake, mesh)["blocks_moe"]["moe"]["wi"]["w"]
        assert spec == jax.sharding.PartitionSpec(None, "model", "data", None)

    def test_embed_vocab_sharded(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"embed": {"w": jax.ShapeDtypeStruct((129280, 7168),
                                                    jnp.bfloat16)}}
        spec = SH.param_specs(fake, mesh)["embed"]["w"]
        assert spec == jax.sharding.PartitionSpec("model", "data")

    def test_wo_swaps_axes(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"blocks": {"attn": {"wo": {"w": jax.ShapeDtypeStruct(
            (32, 2048, 4096), jnp.bfloat16)}}}}
        spec = SH.param_specs(fake, mesh)["blocks"]["attn"]["wo"]["w"]
        assert spec == jax.sharding.PartitionSpec(None, "model", "data")


class TestShardingPolicies:
    def test_dp_only_replicates_params(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        fake = {"blocks": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (32, 4096, 2048), jnp.bfloat16)}}}}
        spec = SH.param_specs(fake, mesh, policy="dp_only")
        got = spec["blocks"]["attn"]["wq"]["w"]
        assert got == jax.sharding.PartitionSpec(None, "data", None)

    def test_dp_only_batch_uses_model_axis(self):
        from repro.dist import sharding as SH
        mesh = _MeshStub(data=16, model=16)
        assert SH.batch_axes(mesh, "dp_only") == ("data", "model")
        assert SH.batch_axes(mesh, "tp") == ("data",)
