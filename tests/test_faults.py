"""Fault-injection harness + runtime graceful degradation.

Covers the full resilience stack added around the engine dispatch and the
train loop: the config-armed injector (ft/inject.py), the execute-with-
fallback / quarantine / probe arc in core/conv.py, plan-cache poisoning
(kernels/autotune.py), the in-graph numerical guard in train/train_step.py,
the loop-side GuardState escalation ladder, async-checkpoint exception
capture, restore-with-fallback over corrupt checkpoints, heartbeat grace,
and serve deadlines.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import conv
from repro.core.config import config
from repro.core.conv import conv2d, dispatch_events, reset_dispatch_events
from repro.ckpt import checkpoint as CKPT
from repro.ft import inject
from repro.ft.failures import (GuardState, HeartbeatTable,
                               make_guard_restart_plan)
from repro.ft.inject import InjectedFault, parse_fault_spec
from repro.optim import adamw
from repro.train import train_step as TS


@pytest.fixture(autouse=True)
def _disarmed():
    """Every test starts and ends with the injector disarmed and every
    introspection surface clean (``obs.reset_all`` covers the dispatch/
    quarantine/plan/fault counters and the obs bus)."""
    from repro import obs
    saved = config.snapshot()
    config.update(fault_spec=None)
    obs.reset_all()
    yield
    config.update(**saved)
    config.update(fault_spec=None)
    obs.reset_all()


def _x(b=2):
    return jnp.asarray(np.random.RandomState(0).randn(b, 3, 16, 16),
                       jnp.float32)


def _w():
    return jnp.asarray(np.random.RandomState(1).randn(8, 3, 3, 3) * 0.1,
                       jnp.float32)


# ---------------------------------------------------------------------------
# Spec grammar
# ---------------------------------------------------------------------------

class TestFaultSpec:
    def test_parse_full_grammar(self):
        rules = parse_fault_spec(
            "pallas.*:raise@step3;grad.values:nan@5;ckpt.write:raise~p0.5")
        assert [r.action for r in rules] == ["raise", "nan", "raise"]
        assert rules[0].step == 3 and rules[1].step == 5
        assert rules[2].step is None and rules[2].prob == 0.5

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="match"):
            parse_fault_spec("nonexistent.site:raise")

    def test_unknown_action_rejected(self):
        with pytest.raises(ValueError, match="action"):
            parse_fault_spec("pallas.*:explode")

    def test_config_validates_before_storing(self):
        with pytest.raises(ValueError):
            config.update(fault_spec="bogus.site:raise")
        assert config.fault_spec is None

    def test_config_arms_and_disarms_injector(self):
        config.update(fault_spec="ckpt.write:raise")
        assert inject.armed_rules()
        config.update(fault_spec=None)
        assert not inject.armed_rules()


# ---------------------------------------------------------------------------
# Zero overhead / zero leak when disarmed
# ---------------------------------------------------------------------------

class TestDisarmed:
    def test_fault_point_is_identity(self):
        tree = {"a": jnp.ones(3)}
        assert inject.fault_point("grad.values", value=tree) is tree
        assert inject.fault_point("ckpt.write") is None
        assert inject.fired_events() == []

    def test_unknown_site_only_checked_when_armed(self):
        # Disarmed: the first-line bailout means no validation cost at all.
        assert inject.fault_point("not.a.site", value=1) == 1
        config.update(fault_spec="ckpt.write:raise")
        with pytest.raises(ValueError, match="unregistered fault site"):
            inject.fault_point("not.a.site")


# ---------------------------------------------------------------------------
# Runtime degradation in the dispatch layer
# ---------------------------------------------------------------------------

class TestRuntimeDegradation:
    def test_pallas_failure_degrades_to_exact_result(self):
        x, w = _x(), _w()
        y_ref = conv2d(x, w, stride=2, padding=1, policy="lax")
        config.update(fault_spec="pallas.*:raise")
        inject.set_step(0)
        y = conv2d(x, w, stride=2, padding=1, policy="pallas")
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-5)
        ev = dispatch_events()
        assert ev.get("forward:pallas->bp_phase") == 1
        assert ev.get("forward:bp_phase") == 1
        rf = conv.runtime_failures()
        assert rf and rf[0]["exception"] == "InjectedFault"
        assert rf[0]["survivor"] == "bp_phase"

    def test_gradients_degrade_too(self):
        x, w = _x(), _w()

        def loss(w, policy):
            return jnp.sum(
                conv2d(x, w, stride=2, padding=1, policy=policy) ** 2)

        g_ref = jax.grad(lambda w: loss(w, "lax"))(w)
        config.update(fault_spec="pallas.*:raise")
        inject.set_step(0)
        g = jax.grad(lambda w: loss(w, "pallas"))(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   rtol=1e-3, atol=1e-4)
        ev = dispatch_events()
        assert ev.get("input_grad:pallas->bp_phase") == 1
        assert ev.get("weight_grad:pallas->bp_phase") == 1

    def test_quarantine_skip_probe_recover(self, monkeypatch):
        monkeypatch.setattr(conv, "QUARANTINE_PROBE_AFTER", 2)
        x, w = _x(), _w()
        config.update(fault_spec="pallas.forward.launch:raise@step0")
        inject.set_step(0)
        conv2d(x, w, stride=2, padding=1, policy="pallas")   # fails, degrades
        assert conv.quarantined_engines()
        config.update(fault_spec=None)
        for step in range(1, 4):                # 2 skips, then probe
            inject.set_step(step)
            conv2d(x, w, stride=2, padding=1, policy="pallas")
        ev = dispatch_events()
        assert ev.get("forward:pallas:quarantined") == 2
        assert ev.get("forward:pallas:probe") == 1
        assert ev.get("forward:pallas:recovered") == 1
        assert not conv.quarantined_engines()

    def test_failed_probe_rearms_quarantine(self, monkeypatch):
        monkeypatch.setattr(conv, "QUARANTINE_PROBE_AFTER", 1)
        x, w = _x(), _w()
        config.update(fault_spec="pallas.forward.launch:raise")
        for step in range(3):                   # fail, skip, probe-fail
            inject.set_step(step)
            conv2d(x, w, stride=2, padding=1, policy="pallas")
        ev = dispatch_events()
        assert ev.get("forward:pallas:probe") == 1
        assert "forward:pallas:recovered" not in ev
        assert conv.quarantined_engines()       # re-armed after failed probe

    def test_lax_failure_propagates(self):
        # lax has no fault site, so fault every implicit engine and ask for
        # an impossible run another way: all engines failing must re-raise
        # the FIRST exception rather than silently returning garbage.
        x, w = _x(), _w()
        boom = RuntimeError("engine down")

        def bad_engine(*a, **k):
            raise boom

        eng = dataclasses.replace(conv.ENGINES["lax"], forward=bad_engine)
        with pytest.MonkeyPatch.context() as mp:
            mp.setitem(conv.ENGINES, "lax", eng)
            for name in ("bp_phase", "bp_im2col", "traditional", "pallas"):
                mp.setitem(conv.ENGINES, name,
                           dataclasses.replace(conv.ENGINES[name],
                                               forward=bad_engine))
            with pytest.raises(RuntimeError, match="engine down"):
                conv2d(x, w, stride=2, padding=1, policy="lax")

    def test_reset_clears_quarantine_and_failures(self):
        config.update(fault_spec="pallas.forward.launch:raise")
        inject.set_step(0)
        conv2d(_x(), _w(), stride=2, padding=1, policy="pallas")
        assert conv.runtime_failures() and conv.quarantined_engines()
        reset_dispatch_events()
        assert not conv.runtime_failures()
        assert not conv.quarantined_engines()


# ---------------------------------------------------------------------------
# Plan-cache poisoning
# ---------------------------------------------------------------------------

class TestPlanPoisoning:
    def test_crashing_pallas_poisons_cached_plan(self, tmp_path):
        from repro.kernels import autotune, ops
        saved = config.snapshot()
        try:
            config.update(autotune="cached", plan_cache_dir=str(tmp_path),
                          interpret=True)
            ops.clear_tile_plan_cache()
            autotune.clear_memo()
            ops.reset_plan_events()
            x, w = _x(), _w()
            config.update(fault_spec="pallas.forward.launch:raise")
            inject.set_step(0)
            conv2d(x, w, stride=2, padding=1, policy="pallas")
            store = autotune._load_store()
            assert any(v.get("poisoned")
                       for v in store["entries"].values()), store
            # Cached mode on the poisoned key: analytic plan, counted.
            config.update(fault_spec=None)
            autotune.clear_memo()
            ops.clear_tile_plan_cache()
            reset_dispatch_events()
            y = conv2d(x, w, stride=2, padding=1, policy="pallas")
            assert np.isfinite(np.asarray(y)).all()
            assert ops.plan_events().get("forward_autotune_poisoned", 0) >= 1
        finally:
            config.update(**saved)
            ops.clear_tile_plan_cache()
            autotune.clear_memo()
            ops.reset_plan_events()

    def test_measure_failure_skips_candidate(self, tmp_path):
        from repro.kernels import autotune, ops
        from repro.core.im2col_ref import ConvDims
        saved = config.snapshot()
        try:
            config.update(autotune="measure", autotune_top_k=2,
                          autotune_reps=1, plan_cache_dir=str(tmp_path),
                          interpret=True)
            autotune.clear_memo()
            ops.reset_plan_events()
            config.update(fault_spec="autotune.measure:raise")
            d = ConvDims(B=1, C=4, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=2,
                         P_h=1, P_w=1)
            analytic = None
            with config.override(autotune="off"):
                analytic = ops.forward_plan(d)
            plan = autotune.tuned_plan(
                "forward", d, config.vmem_budget_bytes, analytic)
            assert plan is not None        # analytic fallback, not a crash
            assert ops.plan_events().get(
                "forward_autotune_measure_failed", 0) >= 1
        finally:
            config.update(**saved)
            autotune.clear_memo()
            ops.reset_plan_events()


# ---------------------------------------------------------------------------
# Numerical guard in the train step
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ToyCfg:
    name: str = "toy"
    conv_policy: str = None
    conv_mode: str = None


def _toy_loss(params, batch, cfg):
    loss = jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
    return loss, {"loss": loss}


def _toy_setup():
    params = {"w": jnp.ones((4, 2))}
    opt = adamw.init_state(params)
    good = {"x": jnp.ones((8, 4)), "y": jnp.zeros((8, 2))}
    bad = {"x": jnp.full((8, 4), jnp.nan), "y": jnp.zeros((8, 2))}
    return params, opt, good, bad


class TestTrainGuard:
    def test_guarded_step_matches_unguarded_when_finite(self):
        cfg, opt_cfg = _ToyCfg(), adamw.AdamWConfig(peak_lr=0.1)
        params, opt, good, _ = _toy_setup()
        plain = TS.make_train_step(cfg, opt_cfg, loss=_toy_loss)
        guarded = TS.make_train_step(cfg, opt_cfg, loss=_toy_loss,
                                     guard=True)
        p1, _, m1 = plain(params, opt, good, 0)
        p2, _, m2 = guarded(params, opt, good, 0)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]))
        assert float(m2["guard_bad"]) == 0.0

    def test_non_finite_step_skipped(self):
        cfg, opt_cfg = _ToyCfg(), adamw.AdamWConfig(peak_lr=0.1)
        params, opt, _, bad = _toy_setup()
        guarded = TS.make_train_step(cfg, opt_cfg, loss=_toy_loss,
                                     guard=True)
        p, o, m = guarded(params, opt, bad, 0)
        np.testing.assert_array_equal(np.asarray(p["w"]),
                                      np.asarray(params["w"]))
        assert float(m["guard_bad"]) == 1.0
        assert float(m["guard_streak"]) == 1.0
        assert int(o["step"]) == 0          # optimizer clock did not tick

    def test_streak_engages_clip_then_resets(self):
        cfg, opt_cfg = _ToyCfg(), adamw.AdamWConfig(peak_lr=0.1)
        params, opt, good, bad = _toy_setup()
        guarded = TS.make_train_step(
            cfg, opt_cfg, loss=_toy_loss,
            guard=TS.GuardConfig(clip_after=2, clip_norm=0.5))
        p, o = params, opt
        for step in range(2):
            p, o, m = guarded(p, o, bad, step)
        assert float(m["guard_streak"]) == 2.0
        p2, o2, m2 = guarded(p, o, good, 2)   # recovery step: clip engaged
        assert float(m2["guard_clipped"]) == 1.0
        assert float(m2["guard_streak"]) == 0.0
        assert np.isfinite(np.asarray(p2["w"])).all()

    def test_in_graph_nan_injection_under_jit(self):
        cfg, opt_cfg = _ToyCfg(), adamw.AdamWConfig(peak_lr=0.1)
        params, opt, good, _ = _toy_setup()
        config.update(fault_spec="grad.values:nan@step2")
        step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg, loss=_toy_loss,
                                             guard=True))
        p, o = params, opt
        bad_mask = []
        for step in range(4):
            p, o, m = step_fn(p, o, good, step)
            bad_mask.append(int(m["guard_bad"]))
        assert bad_mask == [0, 0, 1, 0]
        assert np.isfinite(np.asarray(p["w"])).all()


# ---------------------------------------------------------------------------
# GuardState escalation ladder
# ---------------------------------------------------------------------------

class TestGuardState:
    def test_ladder(self):
        gs = GuardState(clip_after=2, rollback_after=4)
        assert gs.observe(False) == "ok"
        assert gs.observe(True) == "skip"
        assert gs.observe(True) == "clip"
        assert gs.observe(True) == "clip"
        assert gs.observe(True) == "rollback"
        gs.rolled_back()
        assert gs.bad_streak == 0 and gs.rollbacks == 1 and gs.total_bad == 4
        assert gs.observe(False) == "ok"

    def test_guard_restart_plan(self):
        gs = GuardState()
        for _ in range(4):
            gs.observe(True)
        plan = make_guard_restart_plan(gs, [10, 20, 30])
        assert plan.failed_workers == []
        assert plan.resume_step == 30
        assert "numerical guard" in plan.note
        assert make_guard_restart_plan(gs, []).resume_step == 0


# ---------------------------------------------------------------------------
# Checkpoint: async failure capture + corruption fallback
# ---------------------------------------------------------------------------

class TestCheckpointResilience:
    def test_async_write_failure_reraised_on_wait(self, tmp_path):
        config.update(fault_spec="ckpt.write:raise")
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)}, blocking=False)
        with pytest.raises(InjectedFault):
            CKPT.wait()
        config.update(fault_spec=None)
        CKPT.save(str(tmp_path), 2, {"x": np.ones(2)}, blocking=False)
        CKPT.wait()                   # clean second write, nothing pending
        assert CKPT.latest_steps(str(tmp_path)) == [2]

    def test_async_write_failure_reraised_on_next_save(self, tmp_path):
        config.update(fault_spec="ckpt.write:raise")
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)}, blocking=False)
        config.update(fault_spec=None)
        with pytest.raises(InjectedFault):
            CKPT.save(str(tmp_path), 2, {"x": np.ones(2)})
        CKPT.wait()

    def test_truncated_array_falls_back_to_older_step(self, tmp_path):
        CKPT.reset_skipped_checkpoints()
        CKPT.save(str(tmp_path), 1, {"x": np.full(2, 1.0)})
        CKPT.save(str(tmp_path), 2, {"x": np.full(2, 2.0)})
        (tmp_path / "step_00000002" / "arr_00000.npy").write_bytes(
            b"\x93NUMPY junk")
        step, tree = CKPT.restore(str(tmp_path))
        assert step == 1 and tree["x"][0] == 1.0
        assert any(s["checkpoint"] == "step_00000002"
                   for s in CKPT.skipped_checkpoints())

    def test_hash_mismatch_falls_back_with_reason(self, tmp_path):
        CKPT.reset_skipped_checkpoints()
        CKPT.save(str(tmp_path), 1, {"x": np.full(2, 1.0)})
        CKPT.save(str(tmp_path), 2, {"x": np.full(2, 2.0)})
        target = tmp_path / "step_00000002" / "arr_00000.npy"
        arr = np.load(target)
        arr[0] = 999.0
        np.save(target, arr)
        step, tree = CKPT.restore(str(tmp_path))
        assert step == 1
        assert any("corruption" in s["reason"]
                   for s in CKPT.skipped_checkpoints())

    def test_missing_commit_skipped_with_reason(self, tmp_path):
        CKPT.reset_skipped_checkpoints()
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)})
        torn = tmp_path / "step_00000002"
        torn.mkdir()
        (torn / "manifest.json").write_text("{}")
        assert CKPT.latest_steps(str(tmp_path)) == [1]
        assert any("COMMIT" in s["reason"]
                   for s in CKPT.skipped_checkpoints())

    def test_explicit_step_never_falls_back(self, tmp_path):
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)})
        CKPT.save(str(tmp_path), 2, {"x": np.ones(2)})
        (tmp_path / "step_00000002" / "arr_00000.npy").write_bytes(b"junk")
        with pytest.raises(IOError, match="not loadable"):
            CKPT.restore(str(tmp_path), step=2)

    def test_foreign_dir_names_ignored(self, tmp_path):
        CKPT.save(str(tmp_path), 1, {"x": np.ones(2)})
        (tmp_path / "step_00000009.tmp").mkdir()     # stale staging dir
        (tmp_path / "step_notanumber").mkdir()
        assert CKPT.latest_steps(str(tmp_path)) == [1]


# ---------------------------------------------------------------------------
# Heartbeat grace period
# ---------------------------------------------------------------------------

class TestHeartbeatGrace:
    def test_never_beaten_gets_grace_period(self):
        hb = HeartbeatTable(n_workers=2, timeout_s=5.0, t0=0.0)
        assert hb.dead(now=3.0) == []           # inside the grace window
        assert hb.dead(now=6.0) == [0, 1]       # grace expired, never beat

    def test_beat_extends_deadline(self):
        hb = HeartbeatTable(n_workers=2, timeout_s=5.0, t0=0.0)
        hb.beat(0, t=4.0)
        assert hb.dead(now=6.0) == [1]
        assert hb.dead(now=10.0) == [0, 1]


# ---------------------------------------------------------------------------
# Site coverage: every registered fault point is actually wired
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_all_known_sites_are_exercised(tmp_path):
    """Arm a never-firing rule (so every fault_point call registers its
    site without disturbing behaviour), drive every failure domain once,
    and require full coverage of KNOWN_SITES -- a new site that is
    registered but never wired (or wired but not registered) fails here."""
    from repro.kernels import autotune, ops
    saved = config.snapshot()
    try:
        config.update(fault_spec="*:raise@step999999",
                      autotune="measure", autotune_top_k=1, autotune_reps=1,
                      plan_cache_dir=str(tmp_path), interpret=True)
        inject.reset_events()
        autotune.clear_memo()
        ops.clear_tile_plan_cache()
        inject.set_step(0)
        # pallas launches (fwd + both grads) and the autotune read/measure/
        # write path:
        x, w = _x(), _w()
        jax.grad(lambda w: jnp.sum(
            conv2d(x, w, stride=2, padding=1, policy="pallas") ** 2))(w)
        # checkpoint write + read:
        CKPT.save(str(tmp_path / "ck"), 0, {"x": np.ones(2)})
        CKPT.restore(str(tmp_path / "ck"))
        # grad.values (in-graph, via the guarded train step):
        params, opt, good, _ = _toy_setup()
        TS.make_train_step(_ToyCfg(), adamw.AdamWConfig(),
                           loss=_toy_loss, guard=True)(params, opt, good, 0)
        # serve.prefill + serve.decode (continuous engine, one request):
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.serve.continuous import ContinuousEngine
        from repro.serve.request import Request as ServeRequest
        scfg = get_smoke_config("smollm_360m")
        eng = ContinuousEngine(
            scfg, M.build_model(scfg).init(jax.random.PRNGKey(0)),
            max_batch=1, max_len=8)
        eng.submit(ServeRequest(rid=0, prompt=[1, 2], max_new=2))
        eng.run()
        missing = set(inject.KNOWN_SITES) - inject.seen_sites()
        assert not missing, f"registered but never exercised: {missing}"
    finally:
        config.update(**saved)
        autotune.clear_memo()
        ops.clear_tile_plan_cache()
        ops.reset_plan_events()


# ---------------------------------------------------------------------------
# Serve deadlines
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_serve_deadline_times_out_single_request():
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serve.engine import Engine, Request

    class Clock:
        t = 0.0

        def __call__(self):
            self.t += 1.0
            return self.t

    cfg = get_smoke_config("smollm_360m")
    params = M.build_model(cfg).init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=24, clock=Clock())
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=6, deadline_s=3.0))
    eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new=6))
    done = {r.rid: r for r in eng.run()}
    assert done[0].status == "timed_out"
    assert len(done[0].out) < 6            # kept partial output
    assert done[1].status == "ok" and len(done[1].out) == 6
    summary = eng.run_summary()
    assert summary["completed"] == 1
    assert summary["timed_out"] == 1
    assert summary["waves"] == 1
