"""End-to-end system tests: training drivers, conv-policy training, serving,
checkpoint-resume equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import ConvSpec, conv2d
from repro.launch import train as train_launcher
from repro.models import build_model
from repro.serve.engine import Engine, Request


def test_cnn_trains_with_bp_im2col_policies():
    """A small strided CNN classifier trains (loss decreases) under every
    backprop engine policy -- uniform AND mixed per-pass -- and all agree
    with lax step-by-step."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(8, 3, 12, 12), jnp.float32)
    y = jnp.asarray(rng.randint(0, 4, 8), jnp.int32)
    spec = ConvSpec.make(stride=2, padding=1)

    def make_loss(policy):
        def loss_fn(params):
            h = conv2d(x, params["w1"], spec, policy)              # (8,8,6,6)
            h = jax.nn.relu(h)
            h = conv2d(h, params["w2"], spec, policy)              # (8,4,3,3)
            logits = h.mean((2, 3))
            logp = jax.nn.log_softmax(logits)
            return -jnp.take_along_axis(logp, y[:, None], 1).mean()
        return loss_fn

    params0 = {"w1": jnp.asarray(rng.randn(8, 3, 3, 3) * 0.2, jnp.float32),
               "w2": jnp.asarray(rng.randn(4, 8, 3, 3) * 0.2, jnp.float32)}
    histories = {}
    policies = ("lax", "traditional", "bp_im2col", "bp_phase",
                "fwd=lax,dgrad=bp_phase,wgrad=bp_im2col")
    for policy in policies:
        params = dict(params0)
        loss_fn = jax.jit(jax.value_and_grad(make_loss(policy)))
        hist = []
        for _ in range(20):
            l, g = loss_fn(params)
            params = jax.tree.map(lambda p, gg: p - 0.1 * gg, params, g)
            hist.append(float(l))
        histories[policy] = hist
        assert hist[-1] < hist[0], f"{policy} failed to descend"
    for policy in policies[1:]:
        np.testing.assert_allclose(histories["lax"], histories[policy],
                                   rtol=1e-3, atol=1e-3, err_msg=policy)


def test_train_launcher_loss_decreases(tmp_path):
    losses = train_launcher.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "64", "--lr", "1e-2",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"])
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_train_resume_is_exact(tmp_path):
    """Crash/restart: resuming from a checkpoint reproduces the uninterrupted
    run exactly (deterministic pipeline + exact state restore)."""
    full = train_launcher.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "12",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "a"),
        "--ckpt-every", "6"])
    # interrupted run: preempted at step 6, then resume to 12 (the schedule
    # still targets 12 total steps, as a real preemption would)
    train_launcher.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "12",
        "--stop-after", "6",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "b"),
        "--ckpt-every", "6"])
    resumed = train_launcher.main([
        "--arch", "smollm-360m", "--smoke", "--steps", "12",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path / "b"),
        "--ckpt-every", "6"])
    np.testing.assert_allclose(full[6:], resumed, rtol=1e-4, atol=1e-5)


def test_grad_accumulation_equivalence():
    """accum_steps=2 over a batch == accum_steps=1 over the same batch."""
    from repro.optim import adamw
    from repro.train import train_step as TS
    cfg = get_smoke_config("smollm_360m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    p1, _, m1 = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(),
                                           accum_steps=1))(
        params, opt, batch, jnp.int32(0))
    p2, _, m2 = jax.jit(TS.make_train_step(cfg, adamw.AdamWConfig(),
                                           accum_steps=2))(
        params, opt, batch, jnp.int32(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_serving_engine_batched():
    cfg = get_smoke_config("smollm_360m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=4, max_len=32)
    rng = np.random.RandomState(0)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab, 6).tolist(),
                    max_new=8) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out) == 8 for r in done)


def test_serving_batched_matches_single():
    """Greedy decode of the same prompt is identical whether served alone or
    in a batch (lockstep wave correctness)."""
    cfg = get_smoke_config("smollm_360m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = list(range(1, 7))
    eng1 = Engine(cfg, params, max_batch=1, max_len=32)
    eng1.submit(Request(rid=0, prompt=prompt, max_new=6))
    solo = eng1.run()[0].out
    eng4 = Engine(cfg, params, max_batch=4, max_len=32)
    for i in range(4):
        eng4.submit(Request(rid=i, prompt=prompt, max_new=6))
    batched = eng4.run()[0].out
    assert solo == batched


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.25 some tokens may drop, but the output stays
    finite and close to the no-drop result."""
    from repro.models import moe as MOE
    cfg = get_smoke_config("moonshot_v1_16b_a3b")
    p = MOE.init_moe(jax.random.PRNGKey(0), cfg, None)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out1, aux = MOE.moe_apply(p, x, cfg)
    out2, _ = MOE.moe_apply(p, x, cfg, capacity=128)
    assert np.isfinite(np.asarray(out1)).all()
    assert float(aux["moe_lb"]) > 0


@pytest.mark.slow
def test_compressed_gradients_still_train():
    """int8 gradient compression with error feedback: training descends and
    tracks the uncompressed trajectory closely (cross-pod all-reduce
    numerics)."""
    from repro.optim import adamw
    from repro.train import train_step as TS
    from repro.data.pipeline import DataConfig, make_batch
    cfg = get_smoke_config("smollm_360m")
    m = build_model(cfg)
    dcfg = DataConfig(seed=3, seq_len=64, global_batch=4, vocab=cfg.vocab)

    def run(compress):
        params = m.init(jax.random.PRNGKey(0))
        opt = adamw.init_state(params)
        step = jax.jit(TS.make_train_step(
            cfg, adamw.AdamWConfig(peak_lr=5e-3), total_steps=20, warmup=2,
            compress_grads=compress))
        hist = []
        for s in range(15):
            batch = jax.tree.map(jnp.asarray, make_batch(cfg, dcfg, s))
            params, opt, metrics = step(params, opt, batch, jnp.int32(s))
            hist.append(float(metrics["loss"]))
        return hist

    plain = run(False)
    comp = run(True)
    assert comp[-1] < comp[0]                         # still descends
    assert abs(comp[-1] - plain[-1]) < 0.15           # tracks closely
