"""Spatial-tiling boundary tests.

Shrinking ``config.vmem_budget_bytes`` must force progressively finer
spatial splits (1x, 2x, 4x) while all three Pallas conv ops keep agreeing
with the lax reference -- there is no all-or-nothing fallback anymore.
Large shapes that used to exceed the budget must now plan onto the Pallas
path, and the fused input gradient must issue exactly ONE pallas_call per
conv regardless of stride.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import config
from repro.core.im2col_ref import ConvDims, conv2d_lax, conv_grads_lax
from repro.kernels import ops
from repro.kernels import tap_gemm as tg

D = ConvDims(B=2, C=8, H_i=16, W_i=16, N=8, K_h=3, K_w=3, S=2, P_h=1, P_w=1)


def _data(d: ConvDims, seed=0):
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(d.B, d.C, d.H_i, d.W_i), jnp.float32)
    w = jnp.asarray(r.randn(d.N, d.C, d.K_h, d.K_w), jnp.float32)
    dy = jnp.asarray(r.randn(d.B, d.N, d.H_o, d.W_o), jnp.float32)
    return x, w, dy


@pytest.fixture(autouse=True)
def _restore_budget():
    old = config.vmem_budget_bytes
    yield
    config.update(vmem_budget_bytes=old)


def _budget_forcing_splits(d: ConvDims, target: int) -> int:
    """Walk the planner's own candidate sequence down to the budget whose
    best-fitting forward plan has exactly ``target`` spatial splits."""
    budget = ops.forward_plan(d, 1 << 40).bytes_needed
    for _ in range(64):
        plan = ops.forward_plan(d, budget)
        assert plan.fits, f"planner gave up before reaching {target} splits"
        if plan.spatial_splits == target:
            return budget
        assert plan.spatial_splits < target, (
            f"candidate sequence skipped {target} splits "
            f"(got {plan.spatial_splits})")
        budget = plan.bytes_needed - 1
    pytest.fail(f"no budget found for {target} spatial splits")


@pytest.mark.parametrize("target_splits", [1, 2, 4])
def test_budget_forces_spatial_splits(target_splits):
    x, w, dy = _data(D)
    want_y = conv2d_lax(x, w, D)
    want_di, want_dw = conv_grads_lax(x, w, dy, D)
    base_y = ops.conv2d_forward(x, w, D)          # full default budget
    base_di = ops.conv2d_input_grad(dy, w, D)
    base_dw = ops.conv2d_weight_grad(x, dy, D)

    config.update(vmem_budget_bytes=_budget_forcing_splits(D, target_splits))
    fp = ops.forward_plan(D)
    assert fp.fits and fp.spatial_splits == target_splits
    assert ops.weight_grad_plan(D).fits
    assert ops.input_grad_plan(D) is not None, (
        "input grad must tile, not fall back, under a reduced budget")

    y = ops.conv2d_forward(x, w, D)
    di = ops.conv2d_input_grad(dy, w, D)
    dw = ops.conv2d_weight_grad(x, dy, D)
    # Tiled vs untiled Pallas: identical math, only the dispatch geometry
    # changed -- agreement at (near-)bit level.
    np.testing.assert_allclose(y, base_y, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(di, base_di, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dw, base_dw, rtol=1e-4, atol=1e-4)
    # And against the lax ground truth.
    np.testing.assert_allclose(y, want_y, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(di, want_di, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(dw, want_dw, rtol=5e-3, atol=5e-3)


def test_spatially_split_plans_stay_correct_across_strides():
    """2x2-ish splits forced on every op at once, swept over strides."""
    for s in (1, 2, 3):
        d = ConvDims(B=1, C=4, H_i=13, W_i=13, N=5, K_h=3, K_w=3, S=s,
                     P_h=1, P_w=1)
        x, w, dy = _data(d, seed=s)
        want_y = conv2d_lax(x, w, d)
        want_di, want_dw = conv_grads_lax(x, w, dy, d)
        config.update(vmem_budget_bytes=_budget_forcing_splits(d, 4))
        assert ops.input_grad_plan(d) is not None
        np.testing.assert_allclose(ops.conv2d_forward(x, w, d), want_y,
                                   rtol=5e-4, atol=5e-4, err_msg=f"S={s}")
        np.testing.assert_allclose(ops.conv2d_input_grad(dy, w, d), want_di,
                                   rtol=5e-4, atol=5e-4, err_msg=f"S={s}")
        np.testing.assert_allclose(ops.conv2d_weight_grad(x, dy, d), want_dw,
                                   rtol=5e-3, atol=5e-3, err_msg=f"S={s}")


def test_large_shapes_take_pallas_path():
    """Regression: realistic layer sizes must plan onto the Pallas path
    (the seed planner returned fits=False / input_grad_plan=None here)."""
    d56 = ConvDims(B=1, C=128, H_i=56, W_i=56, N=128, K_h=3, K_w=3, S=2,
                   P_h=1, P_w=1)
    rep = ops.plan_report(d56)
    assert rep["pallas_path"], rep
    assert rep["input_grad"]["fused"]
    # The shape-level wrapper reports the same dispatch for the same layer.
    from repro.core.conv import conv_plan_report
    assert conv_plan_report((1, 128, 56, 56), (128, 128, 3, 3), 2, 1) == rep

    # ImageNet-scale spatial plane: must fit by SPLITTING, not fall back.
    d224 = ConvDims(B=1, C=64, H_i=224, W_i=224, N=64, K_h=3, K_w=3, S=2,
                    P_h=1, P_w=1)
    fp = ops.forward_plan(d224)
    assert fp.fits and fp.spatial_splits > 1, (
        fp.spatial_splits, fp.bytes_needed)
    assert ops.weight_grad_plan(d224).fits
    assert ops.input_grad_plan(d224) is not None


def test_budget_is_part_of_the_plan_cache_key():
    """Flipping config.vmem_budget_bytes must re-plan, not serve stale
    plans -- the pre-config footgun of mutating ops.VMEM_BUDGET_BYTES and
    hoping the lru key caught it is gone."""
    full = ops.forward_plan(D)
    assert full.spatial_splits == 1
    config.update(vmem_budget_bytes=full.bytes_needed - 1)
    assert ops.forward_plan(D).spatial_splits > 1
    config.update(vmem_budget_bytes=full.bytes_needed)
    assert ops.forward_plan(D).spatial_splits == 1


def test_budget_change_invalidates_plan_cache():
    """config.update(vmem_budget_bytes=...) drops the memoized plans: the
    planner lru re-MISSES after the flip instead of serving a stale hit."""
    ops.forward_plan(D)
    before = ops.tile_plan_cache_info()["forward_plan"]
    ops.forward_plan(D)
    after = ops.tile_plan_cache_info()["forward_plan"]
    assert after.hits == before.hits + 1          # warm: memoized
    config.update(vmem_budget_bytes=config.vmem_budget_bytes - 1)
    cleared = ops.tile_plan_cache_info()["forward_plan"]
    assert cleared.currsize == 0                  # invalidated, not stale
    ops.forward_plan(D)
    again = ops.tile_plan_cache_info()["forward_plan"]
    assert again.misses >= 1 and again.hits == 0  # re-planned fresh


@pytest.mark.parametrize("stride", [1, 2, 3])
def test_input_grad_issues_exactly_one_pallas_call(stride, monkeypatch):
    d = ConvDims(B=1, C=4, H_i=12, W_i=12, N=5, K_h=3, K_w=3, S=stride,
                 P_h=1, P_w=1)
    x, w, dy = _data(d, seed=7)
    calls = []
    real = tg.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(tg.pl, "pallas_call", counting)
    di = ops.conv2d_input_grad(dy, w, d)
    assert len(calls) == 1, (
        f"S={stride}: expected one fused dispatch, got {len(calls)}")
    want_di, _ = conv_grads_lax(x, w, dy, d)
    np.testing.assert_allclose(di, want_di, rtol=5e-4, atol=5e-4)


def test_tap_gemm_spatial_tiles_match_untiled():
    r = np.random.RandomState(3)
    src = jnp.asarray(r.randn(4, 2, 9, 9, 8), jnp.float32)
    taps = [(0, 0, 0), (1, 0, 1), (2, 1, 0), (3, 1, 1)]
    w = jnp.asarray(r.randn(len(taps), 8, 16), jnp.float32)
    full = tg.tap_gemm(src, w, taps, 8, 8, cin_tile=8, cout_tile=16)
    # Non-divisible tiles exercise the internal spatial padding + crop.
    tiled = tg.tap_gemm(src, w, taps, 8, 8, cin_tile=8, cout_tile=16,
                        oh_tile=3, ow_tile=5)
    np.testing.assert_allclose(tiled, full, rtol=1e-6, atol=1e-6)


def test_tap_wgrad_spatial_tiles_match_untiled():
    r = np.random.RandomState(4)
    src = jnp.asarray(r.randn(4, 3, 9, 9, 8), jnp.float32)
    taps = [(0, 0, 0), (1, 0, 1), (2, 1, 0)]
    dy = jnp.asarray(r.randn(3, 8, 8, 16), jnp.float32)
    full = tg.tap_wgrad(src, dy, taps, 8, 8, cin_tile=8, cout_tile=16)
    tiled = tg.tap_wgrad(src, dy, taps, 8, 8, cin_tile=8, cout_tile=16,
                         oh_tile=3, ow_tile=5)
    np.testing.assert_allclose(tiled, full, rtol=1e-5, atol=1e-5)
