"""ConvSpec / EnginePolicy surface: structured geometry, per-pass engine
selection, auto-tuning resolution, introspection and the deprecation shim.

Covers the API-redesign invariants:
  * mixed per-pass policies (three DIFFERENT engines in one backward) match
    the lax reference, including asymmetric strides and dilations;
  * ``dispatch_events()`` records the engine ACTUALLY used per pass;
  * ``policy="auto"`` resolves every pass of every committed
    ``BENCH_kernels.json`` case onto the Pallas path with zero fallbacks;
  * the legacy ``mode=`` / ``cfg.conv_mode`` / ``--conv-mode`` spellings
    keep working, mapped to a uniform policy, with a DeprecationWarning;
  * ``conv_policy(...)`` context override and ``register_engine()`` hook.
"""

import json
import os
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ConvSpec, EnginePolicy, conv2d, conv_policy,
                        dispatch_events, policy_decisions, policy_report,
                        register_engine, reset_dispatch_events,
                        resolve_policy, spec_dims)
from repro.core import conv as C
from repro.core import im2col_ref
from repro.core.im2col_ref import ConvDims

REPO = pathlib.Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# ConvSpec / EnginePolicy objects
# ---------------------------------------------------------------------------

def test_convspec_normalizes_and_hashes():
    a = ConvSpec.make(stride=2, padding=1, dilation=1)
    b = ConvSpec.make(stride=(2, 2), padding=((1, 1), (1, 1)))
    assert a == b and hash(a) == hash(b)
    c = ConvSpec.make(stride=(1, 2), padding=(2, 0), dilation=(3, 1))
    assert (c.s_h, c.s_w) == (1, 2) and (c.d_h, c.d_w) == (3, 1)
    assert not c.symmetric_stride and c.has_dilation
    assert c.effective_kernel(3, 3) == (7, 3)
    with pytest.raises(ValueError):
        ConvSpec.make(stride=0)
    with pytest.raises(ValueError):
        ConvSpec.make(layout="CHWN")


def test_engine_policy_parse_and_coerce():
    p = EnginePolicy.parse("fwd=pallas,dgrad=auto,wgrad=bp_phase")
    assert (p.forward, p.input_grad, p.weight_grad) == \
        ("pallas", "auto", "bp_phase")
    assert EnginePolicy.parse("pallas") == EnginePolicy.uniform("pallas")
    assert EnginePolicy.coerce(None) == EnginePolicy()          # all-auto
    assert EnginePolicy.coerce("dgrad=lax").input_grad == "lax"
    assert EnginePolicy.coerce("dgrad=lax").forward == "auto"
    assert str(EnginePolicy.uniform("lax")) == "lax"
    assert EnginePolicy.parse(str(p)) == p                      # round-trip
    with pytest.raises(ValueError, match="unknown conv pass"):
        EnginePolicy.parse("sideways=lax")
    with pytest.raises(ValueError, match="duplicate"):
        EnginePolicy.parse("fwd=lax,forward=pallas")


# ---------------------------------------------------------------------------
# Mixed per-pass policies: gradient equivalence vs the lax reference
# ---------------------------------------------------------------------------

MIXED_POLICIES = [
    "fwd=lax,dgrad=pallas,wgrad=bp_im2col",
    "fwd=traditional,dgrad=bp_phase,wgrad=pallas",
    "fwd=pallas,dgrad=bp_im2col,wgrad=traditional",
]


def _mixed_case(rng, spec, policy, rtol=2e-3, atol=2e-3):
    x = jnp.asarray(rng.randn(2, 3, 9, 11), jnp.float32)
    w = jnp.asarray(rng.randn(4, 3, 3, 3) * 0.5, jnp.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(jnp.cos(0.1 * conv2d(a, b, spec, pol)))
    np.testing.assert_allclose(
        conv2d(x, w, spec, policy), conv2d(x, w, spec, "lax"),
        rtol=1e-4, atol=1e-4, err_msg=f"{policy} fwd {spec}")
    want = jax.grad(loss("lax"), argnums=(0, 1))(x, w)
    got = jax.grad(loss(policy), argnums=(0, 1))(x, w)
    for a, b, name in zip(want, got, ("dI", "dW")):
        np.testing.assert_allclose(a, b, rtol=rtol, atol=atol,
                                   err_msg=f"{policy} {name} {spec}")


@pytest.mark.parametrize("policy", MIXED_POLICIES)
def test_mixed_policy_grads_match_lax(policy, rng):
    _mixed_case(rng, ConvSpec.make(stride=2, padding=1), policy)
    _mixed_case(rng, ConvSpec.make(stride=3, padding=((2, 0), (0, 1))),
                policy)


@pytest.mark.parametrize("spec", [
    ConvSpec.make(stride=(1, 2), padding=1),
    ConvSpec.make(stride=(2, 3), padding=((1, 0), (0, 1))),
    ConvSpec.make(stride=2, padding=(2, 1), dilation=(2, 1)),
    ConvSpec.make(stride=(1, 2), padding=1, dilation=(1, 2)),
], ids=str)
def test_asym_stride_and_dilation_match_lax(spec, rng):
    """Asymmetric strides / dilations: every policy (auto, uniform implicit
    engines, mixed with capability-gated slots) still equals lax."""
    for policy in ("auto", "bp_phase", "traditional",
                   "fwd=lax,dgrad=pallas,wgrad=bp_im2col"):
        _mixed_case(rng, spec, policy)


def test_lax_reference_matches_native_dilated_conv(rng):
    """The spec's dilation semantics == lax rhs_dilation (sanity anchor for
    the kernel-materialization lowering)."""
    x = jnp.asarray(rng.randn(2, 3, 12, 12), jnp.float32)
    w = jnp.asarray(rng.randn(4, 3, 3, 3) * 0.5, jnp.float32)
    spec = ConvSpec.make(stride=(2, 1), padding=((2, 1), (1, 0)),
                         dilation=(2, 2))
    want = jax.lax.conv_general_dilated(
        x, w, (2, 1), [(2, 1), (1, 0)], rhs_dilation=(2, 2),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    for policy in ("lax", "auto"):
        np.testing.assert_allclose(conv2d(x, w, spec, policy), want,
                                   rtol=1e-4, atol=1e-4, err_msg=policy)


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(
    hi=st.integers(5, 11), k=st.integers(1, 3),
    s_h=st.integers(1, 3), s_w=st.integers(1, 3),
    d_w=st.integers(1, 2),
    p_lo=st.integers(0, 2), p_hi=st.integers(0, 2),
    pick=st.integers(0, len(MIXED_POLICIES) - 1),
    seed=st.integers(0, 2**16),
)
def test_property_mixed_policies_match_lax(hi, k, s_h, s_w, d_w, p_lo, p_hi,
                                           pick, seed):
    """Property: ANY valid geometry (asymmetric strides, dilation,
    asymmetric pads) x mixed per-pass policies == lax autodiff.  Slots an
    engine cannot serve are capability-resolved; the numbers must still
    match exactly."""
    keff_w = (k - 1) * d_w + 1
    if p_lo > keff_w - 1 or p_hi > keff_w - 1:
        return
    if hi + p_lo + p_hi < keff_w or hi + p_lo + p_hi < k:
        return
    spec = ConvSpec.make(stride=(s_h, s_w), dilation=(1, d_w),
                         padding=((p_lo, p_hi), (p_hi, p_lo)))
    d = spec_dims((2, 2, hi, hi), (3, 2, k, k), spec)
    if d.H_o < 1 or d.W_o < 1:
        return
    try:
        d.validate()
    except AssertionError:
        return                      # outside every implicit engine: skip
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(2, 2, hi, hi), jnp.float32)
    w = jnp.asarray(r.randn(3, 2, k, k) * 0.5, jnp.float32)

    def loss(pol):
        return lambda a, b: jnp.sum(jnp.sin(conv2d(a, b, spec, pol)))
    want = jax.grad(loss("lax"), argnums=(0, 1))(x, w)
    for policy in ("auto", MIXED_POLICIES[pick]):
        got = jax.grad(loss(policy), argnums=(0, 1))(x, w)
        for a, b, name in zip(want, got, ("dI", "dW")):
            np.testing.assert_allclose(
                a, b, rtol=5e-3, atol=5e-3,
                err_msg=f"{policy} {name} {spec}")


# ---------------------------------------------------------------------------
# Introspection: the engine actually used, per pass
# ---------------------------------------------------------------------------

def test_training_step_runs_three_different_engines(rng):
    """One jitted training step under a mixed policy: forward, input-grad
    and weight-grad each dispatch a DIFFERENT engine, and dispatch_events()
    records exactly which."""
    x = jnp.asarray(rng.randn(4, 3, 12, 12), jnp.float32)
    y = jnp.asarray(rng.randint(0, 4, 4), jnp.int32)
    params = {"w1": jnp.asarray(rng.randn(8, 3, 3, 3) * 0.2, jnp.float32),
              "w2": jnp.asarray(rng.randn(4, 8, 3, 3) * 0.2, jnp.float32)}
    spec = ConvSpec.make(stride=2, padding=1)
    policy = EnginePolicy(forward="lax", input_grad="pallas",
                          weight_grad="bp_im2col")

    def loss_fn(p):
        h = jax.nn.relu(conv2d(x, p["w1"], spec, policy))
        logits = conv2d(h, p["w2"], spec, policy).mean((2, 3))
        return -jnp.take_along_axis(jax.nn.log_softmax(logits),
                                    y[:, None], 1).mean()

    reset_dispatch_events()
    step = jax.jit(jax.value_and_grad(loss_fn))
    loss0, grads = step(params)
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss1, _ = step(params2)
    assert float(loss1) < float(loss0)          # it actually trains
    ev = dispatch_events()
    assert ev.get("forward:lax", 0) >= 2        # two conv layers
    assert ev.get("input_grad:pallas", 0) >= 2
    assert ev.get("weight_grad:bp_im2col", 0) >= 2
    # No pass leaked onto an engine the policy did not name.
    assert not any(k.startswith("forward:") and k != "forward:lax"
                   for k in ev), ev
    assert not any(k.startswith("input_grad:") and k != "input_grad:pallas"
                   for k in ev), ev
    assert not any(k.startswith("weight_grad:")
                   and k != "weight_grad:bp_im2col" for k in ev), ev


def test_train_step_threads_mixed_policy_to_dispatch():
    """make_train_step(conv_policy=<mixed>) reaches the conv dispatch: the
    model's depthwise temporal convs record the three per-pass engines."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train import train_step as TS
    cfg = get_smoke_config("mamba2_370m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    reset_dispatch_events()
    step = jax.jit(TS.make_train_step(
        cfg, adamw.AdamWConfig(peak_lr=1e-3), total_steps=10, warmup=1,
        conv_policy="fwd=lax,dgrad=bp_phase,wgrad=bp_im2col"))
    _, _, metrics = step(params, opt, batch, jnp.int32(0))
    assert np.isfinite(float(metrics["loss"]))
    ev = dispatch_events()
    assert ev.get("forward:lax", 0) >= 1, ev
    assert ev.get("input_grad:bp_phase", 0) >= 1, ev
    assert ev.get("weight_grad:bp_im2col", 0) >= 1, ev


def test_fallback_reasons_are_recorded(rng):
    """A capability-gated slot resolves to a capable engine AND records
    why.  Asymmetric strides are served natively by the per-axis tap
    tables now, so the built-in gate exercised here is the paper-geometry
    constraint (P > K-1)."""
    x = jnp.asarray(rng.randn(1, 2, 12, 12), jnp.float32)
    w = jnp.asarray(rng.randn(2, 2, 3, 3), jnp.float32)
    spec = ConvSpec.make(stride=2, padding=3)   # P > K-1: outside the paper
    reset_dispatch_events()
    conv2d(x, w, spec, "pallas")
    ev = dispatch_events()
    assert ev.get("forward:lax", 0) >= 1, ev    # only lax serves this
    decs = [d for d in policy_decisions()
            if d["pass"] == "forward" and d["requested"] == "pallas"]
    assert decs and "outside the paper" in decs[0]["reason"], decs
    # The flip side of PR 4: an asymmetric stride is NOT a capability gap
    # any more -- pallas keeps the pass.
    reset_dispatch_events()
    conv2d(x, w, ConvSpec.make(stride=(1, 2), padding=1), "pallas")
    assert dispatch_events().get("forward:pallas", 0) >= 1


def test_auto_policy_on_committed_bench_cases_is_all_pallas():
    """Acceptance: policy='auto' selects the Pallas path with zero
    fallbacks on every BENCH_kernels.json case."""
    with open(REPO / "BENCH_kernels.json") as f:
        record = json.load(f)
    assert record["cases"], "empty benchmark baseline"
    assert any(dm["dims"].get("S_w", -1) > 0 for dm in record["cases"]), \
        "baseline lost its asymmetric-stride case"
    assert any(dm["dims"].get("D_h", 1) > 1 for dm in record["cases"]), \
        "baseline lost its dilated case"
    for case in record["cases"]:
        dm = case["dims"]
        d = ConvDims(B=dm["B"], C=dm["C"], H_i=dm["H_i"], W_i=dm["W_i"],
                     N=dm["N"], K_h=dm["K_h"], K_w=dm["K_w"], S=dm["S"],
                     S_w=dm.get("S_w", -1), D_h=dm.get("D_h", 1),
                     D_w=dm.get("D_w", 1), P_h=dm["P_h"], P_w=dm["P_w"])
        res = resolve_policy(d, "auto")
        for pass_name, info in res.items():
            assert info["engine"] == "pallas", (dm, pass_name, info)


def test_auto_prefers_native_path_at_stride_1():
    """The shape-dependent rule: stride 1 has no zero-space, so auto stays
    on the dense native path instead of paying the Pallas dispatch."""
    d = ConvDims(B=2, C=8, H_i=16, W_i=16, N=8, K_h=3, K_w=3, S=1,
                 P_h=1, P_w=1)
    res = resolve_policy(d, "auto")
    assert all(v["engine"] == "bp_phase" for v in res.values()), res


def test_empty_output_plane_raises_for_every_engine(rng):
    """A mis-sized layer (effective kernel larger than the padded input)
    fails at trace time with a clear message instead of training on empty
    activations -- for lax too, not just the implicit engines."""
    x = jnp.asarray(rng.randn(1, 2, 8, 8), jnp.float32)
    w = jnp.asarray(rng.randn(3, 2, 3, 3), jnp.float32)
    spec = ConvSpec.make(stride=2, padding=0, dilation=4)   # K_eff = 9 > 8
    for policy in ("lax", "bp_phase", "auto"):
        with pytest.raises(ValueError, match="output plane is empty"):
            conv2d(x, w, spec, policy)


def test_conv_plan_report_covers_asym_and_dilated():
    """Asymmetric strides and dilations are planner-eligible: per-axis tap
    tables plan them like any other geometry, and the dilated tap count
    reflects the zero-skipping (real taps, not the zero-dilated extent)."""
    from repro.core.conv import conv_plan_report
    rep = conv_plan_report((2, 4, 12, 12), (8, 4, 3, 3), stride=(1, 2),
                           padding=1)
    assert rep["pallas_path"] is True
    assert rep["phases"] == 2                    # s_h * s_w = 1 * 2
    assert conv_plan_report((2, 4, 12, 12), (8, 4, 3, 3), stride=2,
                            padding=1)["pallas_path"] is True
    rep2 = conv_plan_report((2, 4, 12, 12), (8, 4, 3, 3), stride=2,
                            padding=2, dilation=2)
    assert rep2["pallas_path"] is True
    assert rep2["kernel_taps"] == {"real": 9, "materialized": 25}
    assert rep2["forward"]["taps"] == 9          # not 25: zeros skipped


def test_policy_report_shapes():
    rep = policy_report((2, 16, 32, 32), (32, 16, 3, 3),
                        ConvSpec.make(stride=2, padding=1), "auto")
    assert rep["pallas_path"] is True
    assert rep["plan"]["pallas_path"] is True
    rep2 = policy_report((2, 16, 32, 32), (32, 16, 3, 3),
                         ConvSpec.make(stride=(1, 2), padding=1), "auto")
    assert rep2["pallas_path"] is True           # per-axis tap tables
    assert rep2["plan"]["pallas_path"] is True
    assert rep2["plan"]["phases"] == 2


# ---------------------------------------------------------------------------
# conv_policy context manager + register_engine hook
# ---------------------------------------------------------------------------

def test_conv_policy_context_overrides_everything(rng):
    x = jnp.asarray(rng.randn(1, 2, 8, 8), jnp.float32)
    w = jnp.asarray(rng.randn(2, 2, 3, 3), jnp.float32)
    spec = ConvSpec.make(stride=2, padding=1)
    reset_dispatch_events()
    with conv_policy("traditional"):
        conv2d(x, w, spec, "pallas")        # override beats the per-call
        with conv_policy("dgrad=lax"):      # innermost wins (others auto)
            jax.grad(lambda a: conv2d(a, w, spec, "pallas").sum())(x)
    ev = dispatch_events()
    assert ev.get("forward:traditional", 0) >= 1, ev
    assert ev.get("input_grad:lax", 0) >= 1, ev
    assert ev.get("input_grad:pallas", 0) == 0, ev
    # ...and the override is gone afterwards.
    reset_dispatch_events()
    conv2d(x, w, spec, "pallas")
    assert dispatch_events().get("forward:pallas", 0) >= 1


def test_register_engine_hook(rng):
    """A user engine registered at runtime is selectable per pass and shows
    up in the dispatch introspection."""
    calls = {"n": 0}

    def counting_forward(x, w, d):
        calls["n"] += 1
        return im2col_ref.conv2d_lax(x, w, d)

    name = "counting_lax"
    if name not in C.ENGINES:
        register_engine(name, counting_forward,
                        C._lax_input_grad, C._lax_weight_grad,
                        asym_stride=True, paper_geometry=False)
    with pytest.raises(ValueError, match="already registered"):
        register_engine(name, counting_forward, C._lax_input_grad,
                        C._lax_weight_grad)
    x = jnp.asarray(rng.randn(1, 2, 8, 8), jnp.float32)
    w = jnp.asarray(rng.randn(2, 2, 3, 3), jnp.float32)
    spec = ConvSpec.make(stride=2, padding=1)
    reset_dispatch_events()
    got = conv2d(x, w, spec, f"fwd={name},dgrad=auto,wgrad=auto")
    np.testing.assert_allclose(got, conv2d(x, w, spec, "lax"),
                               rtol=1e-4, atol=1e-4)
    assert calls["n"] >= 1
    assert dispatch_events().get(f"forward:{name}", 0) >= 1


def test_nhwc_layout(rng):
    x = jnp.asarray(rng.randn(2, 3, 10, 10), jnp.float32)
    w = jnp.asarray(rng.randn(4, 3, 3, 3) * 0.5, jnp.float32)
    want = conv2d(x, w, ConvSpec.make(stride=2, padding=1), "lax")
    xn = jnp.transpose(x, (0, 2, 3, 1))
    spec = ConvSpec.make(stride=2, padding=1, layout="NHWC")
    for policy in ("bp_phase", "pallas"):
        yn = conv2d(xn, w, spec, policy)
        np.testing.assert_allclose(jnp.transpose(yn, (0, 3, 1, 2)), want,
                                   rtol=1e-4, atol=1e-4, err_msg=policy)
    # gradients flow through the boundary transposes
    g = jax.grad(lambda a: conv2d(a, w, spec, "bp_phase").sum())(xn)
    g_ref = jax.grad(lambda a: conv2d(
        a, w, ConvSpec.make(stride=2, padding=1), "lax").sum())(x)
    np.testing.assert_allclose(jnp.transpose(g, (0, 3, 1, 2)), g_ref,
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Backward-compat shim (mode= / cfg.conv_mode / --conv-mode)
# ---------------------------------------------------------------------------

def test_legacy_mode_kwarg_warns_and_matches(rng):
    x = jnp.asarray(rng.randn(1, 3, 9, 9), jnp.float32)
    w = jnp.asarray(rng.randn(4, 3, 3, 3) * 0.5, jnp.float32)
    want = conv2d(x, w, ConvSpec.make(stride=2, padding=1), "bp_phase")
    with pytest.warns(DeprecationWarning, match="mode=.* deprecated"):
        got_kw = conv2d(x, w, stride=2, padding=(1, 1), mode="bp_phase")
    with pytest.warns(DeprecationWarning):
        got_pos = conv2d(x, w, 2, (1, 1), "bp_phase")   # legacy positional
    np.testing.assert_allclose(got_kw, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(got_pos, want, rtol=1e-5, atol=1e-6)
    # Loose geometry kwargs WITHOUT mode are non-deprecated sugar.
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error", DeprecationWarning)
        got_sugar = conv2d(x, w, stride=2, padding=1, groups=1)
    np.testing.assert_allclose(got_sugar, want, rtol=1e-5, atol=1e-6)
    with pytest.raises(TypeError, match="not both"):
        conv2d(x, w, stride=2, padding=1, mode="lax", policy="lax")


def test_legacy_1d_mode_kwarg_warns(rng):
    x = jnp.asarray(rng.randn(2, 4, 12), jnp.float32)
    w = jnp.asarray(rng.randn(3, 4, 3) * 0.5, jnp.float32)
    from repro.core import conv1d
    with pytest.warns(DeprecationWarning):
        got = conv1d(x, w, 2, 1, mode="bp_phase")
    np.testing.assert_allclose(got, conv1d(x, w, 2, 1, "bp_phase"),
                               rtol=1e-5, atol=1e-6)


def test_legacy_cfg_conv_mode_warns():
    import dataclasses
    from repro.configs import get_smoke_config
    cfg = dataclasses.replace(get_smoke_config("mamba2_370m"),
                              conv_mode="bp_phase")
    with pytest.warns(DeprecationWarning, match="conv_mode is deprecated"):
        assert cfg.conv_engine_policy == "bp_phase"
    cfg2 = get_smoke_config("mamba2_370m")
    assert cfg2.conv_mode is None
    assert cfg2.conv_engine_policy == cfg2.conv_policy == "auto"


def test_legacy_train_step_conv_mode_warns():
    from repro.configs import get_smoke_config
    from repro.optim import adamw
    from repro.train import train_step as TS
    cfg = get_smoke_config("mamba2_370m")
    with pytest.warns(DeprecationWarning, match="conv_mode=.* deprecated"):
        TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                           total_steps=2, warmup=1, conv_mode="bp_phase")
    with pytest.raises(TypeError, match="not both"):
        with pytest.warns(DeprecationWarning):
            TS.make_train_step(cfg, adamw.AdamWConfig(peak_lr=1e-3),
                               total_steps=2, warmup=1,
                               conv_mode="bp_phase", conv_policy="auto")


def test_legacy_cli_conv_mode_maps_and_warns():
    from repro.launch.train import resolve_conv_policy_args
    with pytest.warns(DeprecationWarning, match="--conv-mode is deprecated"):
        assert resolve_conv_policy_args(None, "pallas") == "pallas"
    assert resolve_conv_policy_args("fwd=lax,dgrad=auto,wgrad=auto",
                                    None) == "fwd=lax,dgrad=auto,wgrad=auto"
    with pytest.raises(SystemExit):
        with pytest.warns(DeprecationWarning):
            resolve_conv_policy_args("auto", "pallas")


# ---------------------------------------------------------------------------
# Environment / repo-hygiene gates
# ---------------------------------------------------------------------------

def test_interpret_env_var_override():
    """BPIM2COL_INTERPRET=0 flips repro.kernels.ops.INTERPRET without a
    code edit (the ROADMAP 'flip on real TPU' item)."""
    code = ("import repro.kernels.ops as o; "
            "import sys; sys.stdout.write(str(o.INTERPRET))")
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    for val, want in (("0", "False"), ("false", "False"), ("1", "True"),
                      (None, "True")):
        e = dict(env)
        e.pop("BPIM2COL_INTERPRET", None)
        if val is not None:
            e["BPIM2COL_INTERPRET"] = val
        out = subprocess.run([sys.executable, "-c", code], env=e,
                             capture_output=True, text=True, check=True)
        assert out.stdout == want, (val, out.stdout)


def test_no_raw_mode_strings_outside_shim():
    """Grep-lint: internal call sites must use the structured surface."""
    out = subprocess.run(
        [sys.executable, str(REPO / "scripts" / "check_no_raw_mode.py"),
         str(REPO)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr or out.stdout


def test_docs_capability_matrix_matches_registry():
    """The docs lane's code-vs-docs gate: docs/ENGINES.md capability matrix
    == the ENGINES registry's declared flags."""
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable,
         str(REPO / "scripts" / "check_docs_capabilities.py"), str(REPO)],
        capture_output=True, text=True, env=env)
    assert out.returncode == 0, out.stderr or out.stdout


def test_markdown_links_resolve():
    """The docs lane's link gate: every relative link in docs/ + README
    points at an existing file (and anchor)."""
    out = subprocess.run(
        [sys.executable,
         str(REPO / "scripts" / "check_markdown_links.py"), str(REPO)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stderr or out.stdout


def test_bench_compare_detects_regressions():
    """The --compare gate: slowdown > tolerance or a pallas-path loss in a
    new record vs the baseline record fails."""
    sys.path.insert(0, str(REPO / "benchmarks"))
    try:
        import bench_kernels as BK
    finally:
        sys.path.pop(0)
    dims = {"B": 1, "C": 4, "H_i": 12, "W_i": 12, "N": 8, "K_h": 3,
            "K_w": 3, "S": 2, "P_h": 1, "P_w": 1}
    base = {"cases": [{
        "dims": dims, "fits": True,
        "timings_us": {"case": "t", "grad_auto_us": 100.0},
        "auto_policy": {"forward": "pallas", "input_grad": "pallas",
                        "weight_grad": "pallas"}}]}
    ok = {"cases": [{
        "dims": dims, "fits": True,
        "timings_us": {"case": "t", "grad_auto_us": 110.0},
        "auto_policy": {"forward": "pallas", "input_grad": "pallas",
                        "weight_grad": "pallas"}}]}
    assert BK.compare_records(ok, base, tolerance=0.15) == []
    slow = {"cases": [{**ok["cases"][0],
                       "timings_us": {"case": "t", "grad_auto_us": 130.0}}]}
    assert any("grad_auto_us" in p
               for p in BK.compare_records(slow, base, tolerance=0.15))
    unfit = {"cases": [{**ok["cases"][0], "fits": False,
                        "auto_policy": {"forward": "bp_phase",
                                        "input_grad": "pallas",
                                        "weight_grad": "pallas"}}]}
    problems = BK.compare_records(unfit, base, tolerance=0.15)
    assert any("Pallas path" in p for p in problems), problems
    assert any("auto policy regressed" in p for p in problems), problems
    # A dropped/renamed timing column must not pass vacuously.
    dropped = {"cases": [{**ok["cases"][0],
                          "timings_us": {"case": "t"}}]}
    assert any("missing from the new record" in p
               for p in BK.compare_records(dropped, base, tolerance=0.15))
    # Nor may dropping a whole benchmark case.
    assert any("case" in p and "missing" in p
               for p in BK.compare_records({"cases": []}, base,
                                           tolerance=0.15))
    # Telemetry overhead is an ABSOLUTE gate (enabling telemetry must not
    # slow the compiled step), while the off/on arm columns are exempt
    # from the baseline-relative wall-clock diff -- their drift is not a
    # regression, the ratio is the contract.
    tele_base = {"cases": [{
        **ok["cases"][0],
        "timings_us": {"case": "t", "grad_auto_us": 100.0,
                       "telemetry_off_us": 100.0,
                       "telemetry_on_us": 101.0,
                       "telemetry_overhead": 1.01}}]}
    drifted = {"cases": [{
        **ok["cases"][0],
        "timings_us": {"case": "t", "grad_auto_us": 100.0,
                       "telemetry_off_us": 300.0,     # noisy arms, ok
                       "telemetry_on_us": 303.0,
                       "telemetry_overhead": 1.01}}]}
    assert BK.compare_records(drifted, tele_base, tolerance=0.15) == []
    slowed = {"cases": [{
        **ok["cases"][0],
        "timings_us": {"case": "t", "grad_auto_us": 100.0,
                       "telemetry_off_us": 100.0,
                       "telemetry_on_us": 108.0,
                       "telemetry_overhead": 1.08}}]}
    assert any("telemetry_overhead" in p
               for p in BK.compare_records(slowed, tele_base,
                                           tolerance=0.15))
