"""Serving-path coverage for stateful decoders and auxiliary heads.

The wave-batched Engine must work identically for cache-based attention,
recurrent-state (RG-LRU) and SSM-state (Mamba2) decoders; MTP and MoE aux
losses must actually reach the training objective.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.serve.engine import Engine, Request
from repro.train import losses as LO
from repro.train import train_step as TS


@pytest.mark.parametrize("arch", ["mamba2_370m", "recurrentgemma_9b",
                                  "moonshot_v1_16b_a3b"])
def test_engine_serves_stateful_archs(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=24)
    rng = np.random.RandomState(0)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.randint(1, cfg.vocab, 5).tolist(),
                           max_new=6))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 6 for r in done)
    assert all(all(0 <= t < cfg.vocab for t in r.out) for r in done)


def test_engine_greedy_deterministic():
    cfg = get_smoke_config("mamba2_370m")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = [3, 1, 4, 1, 5]
    outs = []
    for _ in range(2):
        eng = Engine(cfg, params, max_batch=2, max_len=24)
        eng.submit(Request(rid=0, prompt=prompt, max_new=5))
        outs.append(eng.run()[0].out)
    assert outs[0] == outs[1]


def test_mtp_loss_reaches_objective():
    """DeepSeek MTP: the auxiliary head contributes to the training loss."""
    cfg = get_smoke_config("deepseek_v3_671b")
    assert cfg.mtp_depth == 1
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    logits, aux = m.forward(params, batch)
    assert "mtp_logits" in aux
    assert aux["mtp_logits"].shape == logits.shape
    loss, metrics = LO.train_loss(logits, aux, batch)
    assert "mtp_ce" in metrics and "moe_lb" in metrics
    # total strictly exceeds plain CE (aux terms are positive)
    assert float(loss) > float(metrics["ce"])


def test_moe_aux_gradients_flow_to_router():
    """The load-balance loss must produce non-zero router gradients."""
    cfg = get_smoke_config("moonshot_v1_16b_a3b")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    grads = jax.grad(lambda p: TS.loss_fn(p, batch, cfg)[0])(params)
    router_g = grads["blocks_moe"]["moe"]["router"]["w"]
    assert float(jnp.abs(router_g).sum()) > 0


def test_hybrid_long_decode_window_semantics():
    """RecurrentGemma decode at positions far beyond the window must only
    attend to the last `window` cached tokens (ring-of-window semantics are
    emulated by the mask; verify old positions don't affect the output)."""
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma_9b"),
                              local_window=8)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    B, L = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, L), 0, cfg.vocab)
    # run A: normal
    cache = m.init_cache(B, L)
    for t in range(L):
        lg_a, cache = m.decode_step(params, cache, toks[:, t], jnp.int32(t))
    # run B: same suffix, different early tokens -- the recurrent state DOES
    # carry early context (that's the point of RG-LRU), so only check that
    # the attention window masking keeps logits finite and shaped.
    assert lg_a.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(lg_a, np.float32)).all()
