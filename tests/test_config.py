"""repro.config: the unified runtime-configuration surface.

Covers the api_redesign contract: env-var precedence at init, frozen
attribute surface, validated update()/override() scoping, plan-cache
invalidation on plan-affecting changes, the post-import env-mutation
deprecation shim, and the legacy module-constant aliases
(ops.INTERPRET / ops.VMEM_BUDGET_BYTES, mamba2.CHUNK,
attention.BLOCKWISE_KV_THRESHOLD, transformer.SCAN_UNROLL).
"""

import warnings

import pytest

import repro
from repro.core.config import AUTOTUNE_MODES, FIELDS, GlobalConfig, config
from repro.core.im2col_ref import ConvDims
from repro.kernels import ops

D = ConvDims(B=1, C=4, H_i=8, W_i=8, N=4, K_h=3, K_w=3, S=2, P_h=1, P_w=1)


@pytest.fixture(autouse=True)
def _restore_config():
    saved = config.snapshot()
    yield
    config.update(**saved)


# ---------------------------------------------------------------------------
# Construction / env precedence
# ---------------------------------------------------------------------------

def test_defaults_without_env():
    c = GlobalConfig(env={})
    assert c.interpret is True
    assert c.vmem_budget_bytes == 14 * 1024 * 1024
    assert c.autotune == "off"
    assert c.autotune_top_k == 4 and c.autotune_reps == 3
    assert c.plan_cache_dir is None and c.remat is None
    assert c.ssd_chunk == 128 and c.blockwise_kv_threshold == 1024
    assert c.scan_unroll == 1


def test_env_initialization_wins_over_defaults():
    c = GlobalConfig(env={"BPIM2COL_INTERPRET": "0",
                          "REPRO_VMEM_BUDGET_BYTES": "1048576",
                          "REPRO_AUTOTUNE": "cached",
                          "REPRO_SSD_CHUNK": "64",
                          "REPRO_REMAT": "block"})
    assert c.interpret is False
    assert c.vmem_budget_bytes == 1 << 20
    assert c.autotune == "cached"
    assert c.ssd_chunk == 64
    assert c.remat == "block"


@pytest.mark.parametrize("raw,expect", [
    ("1", True), ("true", True), ("yes", True), ("", True),
    ("0", False), ("false", False), ("no", False), ("off", False),
    ("FALSE", False), ("Off", False),
])
def test_interpret_env_parsing_matches_historical_rule(raw, expect):
    assert GlobalConfig(env={"BPIM2COL_INTERPRET": raw}).interpret is expect


def test_repro_config_is_the_singleton():
    assert repro.config is config


def test_snapshot_is_a_plain_copy():
    snap = config.snapshot()
    assert set(snap) == set(FIELDS)
    snap["vmem_budget_bytes"] = -1          # mutating the copy changes
    assert config.vmem_budget_bytes != -1   # nothing


# ---------------------------------------------------------------------------
# Frozen surface + validation
# ---------------------------------------------------------------------------

def test_direct_assignment_raises():
    with pytest.raises(AttributeError, match="frozen"):
        config.vmem_budget_bytes = 1


def test_unknown_field_read_and_update_raise():
    with pytest.raises(AttributeError, match="no field"):
        config.not_a_field
    with pytest.raises(ValueError, match="unknown config field"):
        config.update(not_a_field=1)


@pytest.mark.parametrize("kw", [
    {"autotune": "sometimes"},
    {"autotune_top_k": 0},
    {"autotune_reps": -1},
    {"vmem_budget_bytes": "big"},
    {"interpret": "yes"},
    {"plan_cache_dir": 7},
    {"ssd_chunk": 0},
])
def test_update_validates(kw):
    with pytest.raises(ValueError):
        config.update(**kw)


def test_autotune_modes_are_closed():
    assert AUTOTUNE_MODES == ("off", "measure", "cached")
    for mode in AUTOTUNE_MODES:
        config.update(autotune=mode)
        assert config.autotune == mode


# ---------------------------------------------------------------------------
# update() / override() semantics
# ---------------------------------------------------------------------------

def test_override_scopes_and_restores_on_exception():
    before = config.vmem_budget_bytes
    with config.override(vmem_budget_bytes=1 << 20, autotune="cached"):
        assert config.vmem_budget_bytes == 1 << 20
        assert config.autotune == "cached"
    assert config.vmem_budget_bytes == before
    with pytest.raises(RuntimeError):
        with config.override(vmem_budget_bytes=1 << 20):
            raise RuntimeError("boom")
    assert config.vmem_budget_bytes == before


def test_update_invalidates_plan_cache_on_budget_change():
    ops.forward_plan(D)
    assert ops.tile_plan_cache_info()["forward_plan"].currsize >= 1
    config.update(vmem_budget_bytes=config.vmem_budget_bytes + 1)
    assert ops.tile_plan_cache_info()["forward_plan"].currsize == 0


def test_update_same_value_does_not_invalidate():
    ops.forward_plan(D)
    size = ops.tile_plan_cache_info()["forward_plan"].currsize
    assert size >= 1
    config.update(vmem_budget_bytes=config.vmem_budget_bytes)
    assert ops.tile_plan_cache_info()["forward_plan"].currsize == size


def test_non_plan_field_update_does_not_invalidate():
    ops.forward_plan(D)
    size = ops.tile_plan_cache_info()["forward_plan"].currsize
    config.update(ssd_chunk=64)
    assert ops.tile_plan_cache_info()["forward_plan"].currsize == size


# ---------------------------------------------------------------------------
# Post-import env mutation: deprecated but working
# ---------------------------------------------------------------------------

def test_env_mutation_after_init_warns_and_applies():
    env = {"REPRO_SSD_CHUNK": "128"}
    c = GlobalConfig(env=env)
    assert c.ssd_chunk == 128
    env["REPRO_SSD_CHUNK"] = "256"
    with pytest.warns(DeprecationWarning, match="REPRO_SSD_CHUNK"):
        assert c.ssd_chunk == 256
    with warnings.catch_warnings():         # adopted: no repeat warning
        warnings.simplefilter("error")
        assert c.ssd_chunk == 256


def test_env_deletion_after_init_restores_default():
    env = {"REPRO_SCAN_UNROLL": "4"}
    c = GlobalConfig(env=env)
    assert c.scan_unroll == 4
    del env["REPRO_SCAN_UNROLL"]
    with pytest.warns(DeprecationWarning):
        assert c.scan_unroll == 1


def test_update_supersedes_stale_env():
    """An explicit update() wins over the env var it absorbed -- the next
    read must not 'restore' the stale env value."""
    env = {"REPRO_SSD_CHUNK": "64"}
    c = GlobalConfig(env=env)
    c.update(ssd_chunk=32)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert c.ssd_chunk == 32


# ---------------------------------------------------------------------------
# Legacy module-constant aliases
# ---------------------------------------------------------------------------

def test_ops_legacy_globals_read_through_config():
    assert ops.INTERPRET == config.interpret
    assert ops.VMEM_BUDGET_BYTES == config.vmem_budget_bytes
    with config.override(vmem_budget_bytes=1 << 20):
        assert ops.VMEM_BUDGET_BYTES == 1 << 20


def test_ops_legacy_global_write_warns_and_forwards():
    old = config.vmem_budget_bytes
    with pytest.warns(DeprecationWarning, match="VMEM_BUDGET_BYTES"):
        ops.VMEM_BUDGET_BYTES = 1 << 20
    assert config.vmem_budget_bytes == 1 << 20
    with pytest.warns(DeprecationWarning, match="INTERPRET"):
        ops.INTERPRET = config.interpret
    config.update(vmem_budget_bytes=old)


def test_model_constants_are_config_lookups():
    from repro.models import attention, mamba2, transformer
    with config.override(ssd_chunk=64, scan_unroll=8,
                         blockwise_kv_threshold=2048):
        assert mamba2.CHUNK == 64
        assert transformer.SCAN_UNROLL == 8
        assert attention.BLOCKWISE_KV_THRESHOLD == 2048
    assert mamba2.CHUNK == config.ssd_chunk


def test_unknown_module_attr_still_raises():
    from repro.models import mamba2
    with pytest.raises(AttributeError):
        mamba2.NOT_A_CONSTANT
    with pytest.raises(AttributeError):
        ops.NOT_A_CONSTANT
