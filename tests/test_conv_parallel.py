"""Mesh-parallel conv tests (repro.dist.conv_parallel).

Three layers of evidence, cheapest first:

  * host-side plan tests -- ``plan_conv_sharding`` only needs a ``.shape``
    mapping, so per-role degradation, halo math and the recorded reasons
    are pinned without any devices (hypothesis-swept over geometry);
  * a virtual-device matrix -- 8 CPU devices in a subprocess (the XLA flag
    must be set before jax initializes) run every shard role x
    {stride 1/2, dilation, transposed} cell under ``jax.value_and_grad``
    and compare forward/input-grad/weight-grad against the single-device
    lax oracle;
  * an HLO byte audit -- the compiled spatially-sharded forward's
    ``collective-permute`` traffic must equal the tap-derived halo bytes
    EXACTLY: nothing but the kept-tap overlap crosses the wire.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import conv as C
from repro.core.convspec import ConvSpec, ConvTransposeSpec
from repro.dist import conv_parallel as cp
from repro.kernels import ops

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


class StubMesh:
    """Plans only read axis sizes; no devices needed."""

    def __init__(self, **axes):
        self.shape = axes


# ---------------------------------------------------------------------------
# Halo math: shard_halo is the tap table's span, never the padded kernel's
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(taps_h=st.integers(min_value=1, max_value=3),
       taps_w=st.integers(min_value=1, max_value=3),
       dil=st.integers(min_value=1, max_value=3),
       s=st.integers(min_value=1, max_value=3),
       p=st.integers(min_value=0, max_value=4))
def test_shard_halo_matches_kept_tap_span(taps_h, taps_w, dil, s, p):
    """lo + hi == span - stride (the overlap of adjacent stride windows),
    lo == the low pad, and the span agrees with the planners' phase-split
    ``_taps_halo`` of the SAME kept-tap table."""
    k_h, k_w = (taps_h - 1) * dil + 1, (taps_w - 1) * dil + 1
    d = C.spec_dims((2, 3, 48, 48), (4, 3, taps_h, taps_w),
                    ConvSpec.make(stride=s, padding=p, dilation=dil))
    span_h, span_w = ops.tap_span(d)
    # every effective position is a kept tap only at multiples of dil, so
    # the span is the effective extent -- and nothing more
    assert (span_h, span_w) == (k_h, k_w)
    (lo_h, hi_h), (lo_w, hi_w) = ops.shard_halo(d)
    assert (lo_h, lo_w) == (p, p)
    assert lo_h + hi_h == span_h - s
    assert lo_w + hi_w == span_w - s
    taps = ops._forward_taps(ops._canonical(d))
    halo_h, halo_w = ops._taps_halo(taps)
    # phase-split rows and input-plane span measure the same footprint
    assert halo_h == (span_h - 1) // s
    assert halo_w == (span_w - 1) // s


def test_shard_halo_negative_hi_means_crop():
    """1x1 stride-2: adjacent windows skip rows entirely -- hi < 0."""
    d = C.spec_dims((1, 1, 8, 8), (1, 1, 1, 1), ConvSpec.make(stride=2))
    assert ops.shard_halo(d) == ((0, -1), (0, -1))


# ---------------------------------------------------------------------------
# plan_conv_sharding: per-role degradation with recorded reasons
# ---------------------------------------------------------------------------

def _plan(x_shape, w_shape, spec, par, mesh):
    return cp.plan_conv_sharding(x_shape, w_shape, spec, par, mesh)


def test_plan_full_assignment():
    mesh = StubMesh(data=2, model=2, sw=2)
    plan = _plan((4, 8, 16, 16), (6, 8, 3, 3),
                 ConvSpec.make(stride=2, padding=1),
                 cp.ConvParallel(batch=("data",), h="model", cout="sw"),
                 mesh)
    assert plan.roles == ("data", "h", "cout")
    assert plan.tag == "data+h+cout"
    assert plan.halo_h == (1, 0) and plan.dropped == ()


def test_plan_drop_reasons_are_specific():
    mesh = StubMesh(data=2, model=2)
    spec = ConvSpec.make(stride=2, padding=1)
    # indivisible batch drops ONLY the batch role
    plan = _plan((3, 8, 16, 16), (6, 8, 3, 3), spec,
                 cp.ConvParallel(batch=("data",), h="model"), mesh)
    assert plan.roles == ("h",)
    assert ("data", "batch 3 % 2 shards != 0") in plan.dropped
    # VALID-style padding: input != stride x output
    plan = _plan((4, 8, 16, 16), (6, 8, 3, 3), ConvSpec.make(stride=1),
                 cp.ConvParallel(h="model"), mesh)
    (role, why), = plan.dropped
    assert role == "h" and "non-uniform geometry" in why
    # halo wider than the shard block: single-hop exchange impossible
    plan = _plan((4, 8, 8, 8), (6, 8, 7, 7), ConvSpec.make(padding=3),
                 cp.ConvParallel(h="model"), StubMesh(model=4))
    (role, why), = plan.dropped
    assert role == "h" and "exceeds the 2-row shard block" in why
    # grouped conv refuses channel sharding (would split groups)
    plan = _plan((4, 8, 16, 16), (8, 4, 3, 3),
                 ConvSpec.make(padding=1, groups=2),
                 cp.ConvParallel(cin="data", cout="model"), mesh)
    assert plan.roles == ()
    assert all("grouped conv" in why for _, why in plan.dropped)
    # unknown mesh axis / axis claimed twice
    plan = _plan((4, 8, 16, 16), (6, 8, 3, 3), spec,
                 cp.ConvParallel(batch=("data",), cin="data", cout="sw"),
                 mesh)
    reasons = dict(plan.dropped)
    assert "already claimed" in reasons["cin"]
    assert "not in mesh" in reasons["cout"]


def test_plan_size_one_axes_drop_silently():
    plan = _plan((4, 8, 16, 16), (6, 8, 3, 3),
                 ConvSpec.make(stride=2, padding=1),
                 cp.ConvParallel(batch=("data",), h="model"),
                 StubMesh(data=1, model=1))
    assert plan.roles == () and plan.dropped == ()


def test_plan_transposed_channel_counts():
    """Transposed kernels are (C_in, C_out/g, kh, kw): the plan must read
    Cout from dim 1 (x groups), not dim 0."""
    mesh = StubMesh(data=2, model=3)
    plan = _plan((4, 8, 8, 8), (8, 6, 3, 3),
                 ConvTransposeSpec.make(stride=2, padding=1,
                                        output_padding=1),
                 cp.ConvParallel(cin="data", cout="model"), mesh)
    assert plan.transposed and plan.roles == ("cin", "cout")
    # and 6 % a 4-way axis correctly fails
    plan = _plan((4, 8, 8, 8), (8, 6, 3, 3),
                 ConvTransposeSpec.make(stride=2, padding=1,
                                        output_padding=1),
                 cp.ConvParallel(cout="model"), StubMesh(model=4))
    assert ("cout", "cout 6 % 4 shards != 0") in plan.dropped


@settings(max_examples=80, deadline=None)
@given(b=st.integers(min_value=1, max_value=6),
       c=st.integers(min_value=1, max_value=6),
       n=st.integers(min_value=1, max_value=6),
       h=st.integers(min_value=6, max_value=18),
       s=st.integers(min_value=1, max_value=2),
       nd=st.integers(min_value=1, max_value=4),
       nm=st.integers(min_value=1, max_value=4))
def test_plan_never_crashes_and_only_keeps_valid_roles(b, c, n, h, s, nd, nm):
    """Arbitrary (often indivisible) geometry: the plan always returns --
    surviving roles satisfy their invariants, dropped ones carry a reason."""
    mesh = StubMesh(data=nd, model=nm)
    spec = ConvSpec.make(stride=s, padding=1)
    x_shape, w_shape = (b, c, h, h), (n, c, 3, 3)
    try:
        d = C.spec_dims(x_shape, w_shape, spec)
    except Exception:
        return  # degenerate geometry the conv itself would reject
    if d.H_o < 1 or d.W_o < 1:
        return
    plan = _plan(x_shape, w_shape, spec,
                 cp.ConvParallel(batch=("data",), h="model", cin="model",
                                 cout="data"),
                 mesh)
    if plan.batch:
        assert b % nd == 0
    if plan.h:
        blk = h // nm
        assert h % nm == 0 and d.H_o % nm == 0 and h == s * d.H_o
        assert plan.halo_h[0] <= blk and plan.halo_h[1] <= blk
    if plan.cin:
        assert c % nm == 0
    if plan.cout:
        assert n % nd == 0
    # one axis never backs two roles
    claimed = [a for a in (plan.batch_spec, plan.h, plan.cin and "model",
                           plan.cout and "data") if a]
    axes = [a for a in (plan.h, plan.cin, plan.cout) if a] \
        + list(plan.batch)
    assert len(axes) == len(set(axes)), claimed
    for role, why in plan.dropped:
        assert role in cp.ROLES and isinstance(why, str) and why


# ---------------------------------------------------------------------------
# Policy resolution + hook lifecycle
# ---------------------------------------------------------------------------

def test_from_policy_resolution():
    mesh = StubMesh(data=4, model=2)
    tp = cp.ConvParallel.from_policy("tp", mesh)
    assert tp == cp.ConvParallel(batch=("data",), cout="model")
    dp = cp.ConvParallel.from_policy("dp_only", mesh)
    assert dp.batch == ("data", "model") and dp.cout is None
    sp = cp.ConvParallel.from_policy("spatial", mesh)
    assert sp.h == "model" and sp.batch == ("data",)
    rep = cp.ConvParallel.from_policy("tp_rep", mesh)
    assert rep == cp.ConvParallel(batch=("data",))
    pod = cp.ConvParallel.from_policy("tp", StubMesh(pod=2, data=4, model=2))
    assert pod.batch == ("pod", "data")
    with pytest.raises(ValueError, match="unknown conv mesh policy"):
        cp.ConvParallel.from_policy("bogus", mesh)


def test_conv_mesh_context_installs_and_clears_hook():
    assert C.MESH_LOWERING is None
    with cp.conv_mesh("tp"):
        assert C.MESH_LOWERING is cp._maybe_lower
        with cp.conv_mesh("spatial"):       # nesting keeps the hook
            assert C.MESH_LOWERING is cp._maybe_lower
        assert C.MESH_LOWERING is cp._maybe_lower
    assert C.MESH_LOWERING is None
    with cp.conv_mesh(None):                # None: explicit no-op
        assert C.MESH_LOWERING is None
    with pytest.raises(ValueError, match="unknown conv mesh policy"):
        cp.conv_mesh("bogus").__enter__()
    assert C.MESH_LOWERING is None


def test_no_mesh_falls_back_with_event():
    """Hook armed but no mesh anywhere: single-device result + event."""
    import jax
    import jax.numpy as jnp
    C.reset_dispatch_events()
    x = jnp.ones((1, 2, 8, 8), jnp.float32)
    w = jnp.ones((3, 2, 3, 3), jnp.float32)
    spec = ConvSpec.make(stride=2, padding=1)
    with cp.conv_mesh("tp"):
        y = C.conv2d(x, w, spec, "lax")
    assert y.shape == (1, 3, 4, 4)
    assert C.dispatch_events().get("mesh:no_mesh", 0) >= 1
    assert jax.numpy.allclose(y, C.conv2d(x, w, spec, "lax"))


# ---------------------------------------------------------------------------
# dist.sharding: conv kernels are spatial, not matmuls (regression pin)
# ---------------------------------------------------------------------------

def test_param_specs_conv_kernels_shard_cout_only():
    """The 4-D conv-kernel leaf rule: Cout over "model" (dim 0 regular,
    dim 1 transposed/"dec"), spatial dims NEVER sharded -- and the walk
    traverses the autoencoder's per-stage lists."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.dist import sharding as SH
    from repro.models import model as M

    mesh = StubMesh(data=1, model=1)   # size-1: _fit always accepts
    cfg = M.AutoencoderConfig(c_in=3, widths=(16, 32), k=3)
    params = jax.eval_shape(
        lambda: M.init_autoencoder(jax.random.PRNGKey(0), cfg))
    specs = SH.param_specs(params, mesh, "tp")
    assert isinstance(specs["enc"], list) and len(specs["enc"]) == 2
    for layer in specs["enc"]:
        assert layer["w"] == P("model", None, None, None)
    for layer in specs["dec"]:
        assert layer["w"] == P(None, "model", None, None)
    dp = SH.param_specs(params, mesh, "dp_only")
    for stage in ("enc", "dec"):
        for layer in dp[stage]:
            assert layer["w"] == P(None, None, None, None)
    # a 4-D kernel whose kh x kw happens to divide the mesh must still
    # never shard its spatial dims
    big = {"enc": [{"w": jax.ShapeDtypeStruct((8, 8, 4, 4), "float32")}]}
    spec = SH.param_specs(big, StubMesh(data=4, model=4), "tp")
    assert spec["enc"][0]["w"] == P("model", None, None, None)


# ---------------------------------------------------------------------------
# Virtual-device matrix: every role x {stride, dilation, transposed}
# ---------------------------------------------------------------------------

_MATRIX_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, os.path.join(%(root)r, "src"))
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core import conv as C
    from repro.core.convspec import ConvSpec, ConvTransposeSpec
    from repro.dist import conv_parallel as cp

    assert len(jax.devices()) == 8, jax.devices()
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                ("data", "model", "sw"))
    results = []

    def check(tag, x, w, spec, par, want_event, transposed=False):
        C.reset_dispatch_events()
        conv = C.conv2d_transpose if transposed else C.conv2d

        def loss(x_, w_):
            with cp.conv_mesh(par, mesh):
                y = conv(x_, w_, spec, "auto")
            return jnp.sum(jnp.sin(y)), y

        def loss_ref(x_, w_):
            y = conv(x_, w_, spec, "lax")
            return jnp.sum(jnp.sin(y)), y

        (_, y_sh), g_sh = jax.value_and_grad(
            loss, argnums=(0, 1), has_aux=True)(x, w)
        events = dict(C.dispatch_events())
        (_, y_rf), g_rf = jax.value_and_grad(
            loss_ref, argnums=(0, 1), has_aux=True)(x, w)
        results.append({
            "tag": tag,
            "err_y": float(jnp.max(jnp.abs(y_sh - y_rf))),
            "err_dx": float(jnp.max(jnp.abs(g_sh[0] - g_rf[0]))),
            "err_dw": float(jnp.max(jnp.abs(g_sh[1] - g_rf[1]))),
            "sharded_events": sorted(
                k for k in events if k.startswith("mesh:conv2d")),
            "want_event": want_event,
        })

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 8, 16, 16), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (6, 8, 3, 3), jnp.float32)
    s2 = ConvSpec.make(stride=2, padding=1)
    s1 = ConvSpec.make(stride=1, padding=1)

    check("reg s2 data+h+cout", x, w, s2,
          cp.ConvParallel(batch=("data",), h="model", cout="sw"),
          "mesh:conv2d:data+h+cout")
    check("reg s1 data+h+w", x, w, s1,
          cp.ConvParallel(batch=("data",), h="model", w="sw"),
          "mesh:conv2d:data+h+w")
    check("reg s2 cin+cout", x, w, s2,
          cp.ConvParallel(cin="data", cout="model"),
          "mesh:conv2d:cin+cout")
    check("reg s2 w only", x, w, s2, cp.ConvParallel(w="sw"),
          "mesh:conv2d:w")
    check("reg dil2 data+h", x, w,
          ConvSpec.make(stride=1, padding=2, dilation=2),
          cp.ConvParallel(batch=("data",), h="model"),
          "mesh:conv2d:data+h")
    check("reg s1 policy tp", x, w, s1, "tp", "mesh:conv2d:data+cout")

    wt = jax.random.normal(jax.random.PRNGKey(2), (8, 6, 3, 3), jnp.float32)
    ts = ConvTransposeSpec.make(stride=2, padding=1, output_padding=1)
    check("tsp data+h+cin", x, wt, ts,
          cp.ConvParallel(batch=("data",), h="model", cin="sw"),
          "mesh:conv2d_T:data+h+cin", transposed=True)
    check("tsp h+w", x, wt, ts, cp.ConvParallel(h="model", w="sw"),
          "mesh:conv2d_T:h+w", transposed=True)
    check("tsp data+cout", x, wt, ts,
          cp.ConvParallel(batch=("data",), cout="model"),
          "mesh:conv2d_T:data+cout", transposed=True)

    # fallback execution: indivisible B and H run replicated with reasons
    C.reset_dispatch_events()
    x3 = jax.random.normal(key, (3, 8, 15, 16), jnp.float32)
    with cp.conv_mesh(cp.ConvParallel(batch=("data",), h="model"), mesh):
        y = C.conv2d(x3, w, ConvSpec.make(stride=1, padding=1), "lax")
    y_ref = C.conv2d(x3, w, ConvSpec.make(stride=1, padding=1), "lax")
    fb = {
        "events": {k: v for k, v in C.dispatch_events().items()
                   if k.startswith("mesh")},
        "reasons": [p["reason"] for p in C.policy_decisions()
                    if p["pass"] == "mesh"],
        "err": float(jnp.max(jnp.abs(y - y_ref))),
    }
    print(json.dumps({"cells": results, "fallback": fb}))
""")


@pytest.mark.dist
@pytest.mark.slow
def test_virtual_device_matrix_matches_single_device_oracle():
    out = subprocess.run(
        [sys.executable, "-c", _MATRIX_SCRIPT % {"root": ROOT}],
        capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert len(res["cells"]) == 9
    for cell in res["cells"]:
        errs = (cell["err_y"], cell["err_dx"], cell["err_dw"])
        assert max(errs) < 1e-4, cell
        assert cell["want_event"] in cell["sharded_events"], cell
    fb = res["fallback"]
    assert fb["err"] == 0.0
    assert "mesh:fallback" in fb["events"]
    assert fb["events"].get("mesh:drop:data") and fb["events"].get(
        "mesh:drop:h")
    assert any("batch 3 % 2" in r for r in fb["reasons"])
    assert any("15 % 2 shards" in r for r in fb["reasons"])


# ---------------------------------------------------------------------------
# HLO byte audit: the wire carries the tap halos and nothing else
# ---------------------------------------------------------------------------

_HALO_BYTES_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys, json
    sys.path.insert(0, os.path.join(%(root)r, "src"))
    import numpy as np, jax, jax.numpy as jnp
    assert len(jax.devices()) == 8        # lock the backend in BEFORE
    from jax.sharding import Mesh         # dryrun's 512-device default
    from repro.core import conv as C
    from repro.core.convspec import ConvSpec
    from repro.dist import conv_parallel as cp
    from repro.kernels import ops
    from repro.launch import dryrun

    mesh = Mesh(np.array(jax.devices()).reshape(8), ("model",))
    n = 8
    B, Cin, Cout, H, W = 2, 3, 5, 64, 64
    x = jnp.ones((B, Cin, H, W), jnp.float32)
    out = []
    for name, spec in (
            ("k3s1", ConvSpec.make(stride=1, padding=1)),
            ("k3s2", ConvSpec.make(stride=2, padding=1)),
            ("k5d2s1", ConvSpec.make(stride=1, padding=2, dilation=2))):
        k_taps = 3
        w = jnp.ones((Cout, Cin, k_taps, k_taps), jnp.float32)
        d = C.spec_dims(x.shape, w.shape, spec)
        (lo, hi), _ = ops.shard_halo(d)

        def fwd(x_, w_):
            with cp.conv_mesh(cp.ConvParallel(h="model"), mesh):
                return C.conv2d(x_, w_, spec, "lax")

        hlo = jax.jit(fwd).lower(x, w).compile().as_text()
        got = dryrun.collective_bytes(hlo, n)["collective-permute"]
        rows = max(lo, 0) + max(hi, 0)
        want = 4.0 * B * Cin * rows * W    # f32 halo slices, one hop each
        out.append({"case": name, "halo": [lo, hi],
                    "got": got, "want": want})
    print(json.dumps(out))
""")


@pytest.mark.dist
@pytest.mark.slow
def test_halo_exchange_bytes_equal_tap_derived_halos():
    """Exactly ``(lo + hi) * B * C * W * 4`` collective-permute bytes per
    spatially sharded forward: the exchanged halo IS ``shard_halo`` of the
    kept taps -- a stride-2 kernel exchanges ONE row, not two, and a
    dilated kernel's zero taps never cross the wire."""
    out = subprocess.run(
        [sys.executable, "-c", _HALO_BYTES_SCRIPT % {"root": ROOT}],
        capture_output=True, text=True, timeout=600, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-4000:]
    cases = json.loads(out.stdout.strip().splitlines()[-1])
    halos = {c["case"]: tuple(c["halo"]) for c in cases}
    assert halos == {"k3s1": (1, 1), "k3s2": (1, 0), "k5d2s1": (2, 2)}
    for c in cases:
        assert c["got"] == c["want"], c
