"""Continuous-batching engine: token equivalence with the static engine,
slot recycling with clean cache slices, deadline expiry under a fake
clock, per-lane crash isolation via the serve.* fault sites, and the
per-lane position vector path at the models level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.config import config
from repro.ft import inject
from repro.models import build_model
from repro.models import model as M
from repro.serve import cache as SC
from repro.serve.continuous import ContinuousEngine
from repro.serve.engine import Engine
from repro.serve.request import Request


def _setup(arch="smollm_360m"):
    cfg = get_smoke_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, plen=5, max_new=6, seed=0):
    rng = np.random.RandomState(seed)
    return [Request(rid=i, prompt=rng.randint(1, cfg.vocab, plen).tolist(),
                    max_new=max_new) for i in range(n)]


class _Clock:
    """Fake wall clock: +1s per read, so every deadline comparison is
    deterministic regardless of real scheduling."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# Token equivalence: continuous == static (greedy) per request
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm_360m", "mamba2_370m",
                                  "recurrentgemma_9b"])
def test_continuous_matches_static_greedy(arch):
    """Equal-length prompts (the static engine's left-padding is a no-op)
    through both engines: greedy outputs must be token-identical per
    request.  5 requests on 2 lanes forces slot recycling on the way."""
    cfg, params = _setup(arch)
    reqs = _requests(cfg, 5)

    def run(engine_cls):
        eng = engine_cls(cfg, params, max_batch=2, max_len=24)
        for r in _requests(cfg, 5):
            eng.submit(r)
        return {r.rid: r.out for r in eng.run()}

    static, cont = run(Engine), run(ContinuousEngine)
    assert set(static) == set(cont) == {r.rid for r in reqs}
    assert static == cont


def test_continuous_varied_prompt_lengths_match_solo():
    """Prompts of different lengths share lanes; each request's greedy
    output must equal its solo run (padding-free prefill + per-lane
    positions keep lanes independent)."""
    cfg, params = _setup()
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, n).tolist() for n in (3, 7, 5, 4)]

    solo = {}
    for i, p in enumerate(prompts):
        eng = Engine(cfg, params, max_batch=1, max_len=24)
        eng.submit(Request(rid=i, prompt=p, max_new=6))
        solo[i] = eng.run()[0].out

    eng = ContinuousEngine(cfg, params, max_batch=2, max_len=24)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new=6))
    cont = {r.rid: r.out for r in eng.run()}
    assert cont == solo


# ---------------------------------------------------------------------------
# Slot recycling: a freed lane is reused with a clean cache slice
# ---------------------------------------------------------------------------

def test_slot_recycling_clean_cache_slice():
    """3 requests on ONE lane: every request decodes on a lane that just
    held a different request's cache.  Outputs equal to each solo run
    prove the lane insert fully overwrites the recycled slice."""
    cfg, params = _setup()
    reqs = _requests(cfg, 3, plen=6, max_new=5, seed=2)

    solo = {}
    for r in _requests(cfg, 3, plen=6, max_new=5, seed=2):
        eng = Engine(cfg, params, max_batch=1, max_len=24)
        eng.submit(r)
        solo[r.rid] = eng.run()[0].out

    eng = ContinuousEngine(cfg, params, max_batch=1, max_len=24)
    for r in reqs:
        eng.submit(r)
    done = {r.rid: r.out for r in eng.run()}
    assert done == solo
    assert eng.counters["inserts"] == 3
    assert eng.free_lanes() == [0]          # no lane leaked


def test_lane_reset_zeroes_one_lane():
    cfg, _ = _setup()
    from repro.models import transformer as T
    cache = jax.tree.map(lambda c: jnp.ones_like(c),
                         T.init_cache(cfg, 3, 8))
    reset = SC.lane_reset(cache, jnp.int32(1))
    for leaf in jax.tree.leaves(reset):
        assert float(jnp.abs(leaf[:, 1]).sum()) == 0.0
        assert float(jnp.abs(leaf[:, 0]).sum()) > 0.0
        assert float(jnp.abs(leaf[:, 2]).sum()) > 0.0


# ---------------------------------------------------------------------------
# Deadlines under a fake clock
# ---------------------------------------------------------------------------

def test_continuous_deadline_expires_queued_request_at_admission():
    """A request whose deadline lapses while QUEUED is finalized at
    admission time -- zero decode steps are spent on it."""
    cfg, params = _setup()
    eng = ContinuousEngine(cfg, params, max_batch=1, max_len=24,
                           clock=_Clock())
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=6))
    eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new=6,
                       deadline_s=0.5))
    done = {r.rid: r for r in eng.run()}
    assert done[0].status == "ok" and len(done[0].out) == 6
    assert done[1].status == "timed_out"
    assert done[1].out == []                # never admitted, never decoded
    assert eng.counters["timed_out"] == 1
    assert eng.counters["admitted"] == 1


def test_continuous_deadline_expires_mid_stream():
    """An admitted request whose deadline lapses mid-generation keeps its
    partial output and frees the lane."""
    cfg, params = _setup()
    eng = ContinuousEngine(cfg, params, max_batch=1, max_len=40,
                           clock=_Clock())
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=30,
                       deadline_s=5.0))
    r = eng.run()[0]
    assert r.status == "timed_out"
    assert 0 < len(r.out) < 30              # partial output kept
    assert eng.free_lanes() == [0]


# ---------------------------------------------------------------------------
# Failure domain: serve.prefill / serve.decode fault sites
# ---------------------------------------------------------------------------

def test_prefill_fault_fails_request_not_engine():
    cfg, params = _setup()
    saved = config.snapshot()
    try:
        config.update(fault_spec="serve.prefill:raise")
        eng = ContinuousEngine(cfg, params, max_batch=2, max_len=24)
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=4))
        done = eng.run()
        assert [r.status for r in done] == ["failed"]
        assert done[0].out == []
        assert eng.counters["failed"] == 1
        assert eng.free_lanes() == [0, 1]   # no lane leaked
    finally:
        config.update(**saved)
    # Disarmed again: the same engine instance serves cleanly.
    eng.submit(Request(rid=1, prompt=[1, 2, 3], max_new=4))
    r = eng.run()[0]
    assert r.status == "ok" and len(r.out) == 4


def test_decode_fault_finalizes_lane_batch_survives():
    """A decode-step crash on one lane finalizes THAT request with
    status="failed"; requests that finished earlier and requests admitted
    later complete normally."""
    cfg, params = _setup()
    saved = config.snapshot()
    try:
        config.update(fault_spec="serve.decode:raise@step4")
        eng = ContinuousEngine(cfg, params, max_batch=2, max_len=24)
        # rid 0 finishes (1 prefill + 1 decode token) before step 4;
        # rid 1 is the only lane alive at step 4 and crashes there.
        eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=2))
        eng.submit(Request(rid=1, prompt=[1, 2, 3, 4], max_new=10))
        done = {r.rid: r for r in eng.run()}
        assert done[0].status == "ok" and len(done[0].out) == 2
        assert done[1].status == "failed"
        assert 0 < len(done[1].out) < 10    # partial output kept
        assert eng.free_lanes() == [0, 1]
        # The lane is reusable after the crash (the step clock has moved
        # past the armed step, so the new request serves cleanly).
        eng.submit(Request(rid=2, prompt=[5, 6, 7], max_new=3))
        r = eng.run()[0]
        assert r.status == "ok" and len(r.out) == 3
    finally:
        config.update(**saved)
    assert inject.armed_rules() == ()


# ---------------------------------------------------------------------------
# Per-lane position vector path at the models level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm_360m", "deepseek_v3_671b"])
def test_vector_pos_decode_matches_scalar(arch):
    """lane_insert + a (B,) position vector must reproduce each lane's
    scalar-pos batch-1 decode exactly: per-lane rope angles, cache
    scatter and causal masking all line up."""
    cfg, params = _setup(arch)
    max_len = 16
    rng = np.random.RandomState(3)
    prompts = [rng.randint(1, cfg.vocab, n).tolist() for n in (3, 6, 4)]
    b = len(prompts)

    from repro.models import transformer as T
    batch_cache = T.init_cache(cfg, b, max_len)
    solo_logits, next_toks = [], []
    for lane, p in enumerate(prompts):
        logits, src = M.prefill(params, jnp.asarray([p], jnp.int32),
                                cfg, max_len)
        batch_cache = SC.lane_insert(batch_cache, src, jnp.int32(lane))
        tok = int(jnp.argmax(logits[0]))
        next_toks.append(tok)
        # Reference: one scalar-pos decode step on the solo cache.
        ref, _ = M.decode_step(params, src, jnp.asarray([tok], jnp.int32),
                               jnp.int32(len(p)), cfg)
        solo_logits.append(np.asarray(ref[0], np.float32))

    pos = jnp.asarray([len(p) for p in prompts], jnp.int32)
    logits, _ = M.decode_step(params, batch_cache,
                              jnp.asarray(next_toks, jnp.int32), pos, cfg)
    for lane in range(b):
        np.testing.assert_allclose(np.asarray(logits[lane], np.float32),
                                   solo_logits[lane], rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Policy'd conv decode archs ride the continuous path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2_370m", "recurrentgemma_9b"])
def test_conv_policy_threads_through_continuous(arch):
    cfg, params = _setup(arch)
    eng = ContinuousEngine(cfg, params, max_batch=2, max_len=24,
                           conv_policy="bp_phase")
    assert eng.cfg.conv_policy == "bp_phase"
    for r in _requests(cfg, 3, max_new=5, seed=4):
        eng.submit(r)
    done = eng.run()
    assert len(done) == 3
    assert all(r.status == "ok" and len(r.out) == 5 for r in done)
