"""Cross-engine gradient-equivalence matrix for the conv2d custom_vjp.

The system invariant of the paper: for EVERY engine, ``jax.grad`` through
``conv2d(..., spec, policy)`` equals ``jax.grad`` through the lax
reference -- over stride {1, 2, 3}, symmetric and asymmetric padding,
1x1/3x3/5x5 kernels, grouped / depthwise / 1-D convs, and under jit and
vmap.  This is what guarantees a training run under any policy follows the
exact lax trajectory while exercising the BP-im2col datapath.  Policies
here are uniform (one engine for all passes); the mixed per-pass matrix
lives in tests/test_conv_policy.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ConvSpec, conv1d, conv1d_causal, conv2d,
                        depthwise_causal_conv1d)
from repro.core.conv import MODES
from repro.kernels import ops

ENGINE_MODES = [m for m in MODES if m != "lax"]

# (stride, padding, k) sweep: symmetric, zero and asymmetric pads.
SWEEP = [
    (1, (1, 1), 3),
    (2, (1, 1), 3),
    (3, (1, 1), 3),
    (2, (0, 0), 3),
    (2, (0, 0), 1),
    (2, ((2, 0), (0, 1)), 3),          # asymmetric
    (1, ((0, 2), (1, 0)), 3),          # asymmetric, stride 1
    (2, (2, 2), 5),
]


def _data(rng, b=2, c=3, n=4, hi=9, k=3, groups=1):
    x = jnp.asarray(rng.randn(b, c, hi, hi), jnp.float32)
    w = jnp.asarray(rng.randn(n, c // groups, k, k) * 0.5, jnp.float32)
    return x, w


def _grads(policy, spec, x, w):
    def loss(x_, w_):
        y = conv2d(x_, w_, spec, policy)
        return jnp.sum(y * jnp.cos(0.1 * y))   # nonlinear head: dy != const
    return jax.grad(loss, argnums=(0, 1))(x, w)


def _assert_matches_lax(policy, stride, pad, groups, x, w,
                        rtol=2e-3, atol=2e-3):
    spec = ConvSpec.make(stride=stride, padding=pad, groups=groups)
    want = _grads("lax", spec, x, w)
    got = _grads(policy, spec, x, w)
    for a, b, name in zip(want, got, ("dI", "dW")):
        np.testing.assert_allclose(
            a, b, rtol=rtol, atol=atol,
            err_msg=f"{policy} s={stride} p={pad} g={groups} {name}")
    np.testing.assert_allclose(
        conv2d(x, w, spec, policy),
        conv2d(x, w, spec, "lax"),
        rtol=1e-4, atol=1e-4, err_msg=f"{policy} forward")


@pytest.mark.parametrize("mode", ENGINE_MODES)
@pytest.mark.parametrize("stride,pad,k", SWEEP,
                         ids=lambda v: str(v).replace(" ", ""))
def test_grad_matrix_matches_lax(mode, stride, pad, k, rng):
    x, w = _data(rng, k=k)
    _assert_matches_lax(mode, stride, pad, 1, x, w)


@pytest.mark.parametrize("mode", ENGINE_MODES)
@pytest.mark.parametrize("groups,c,n", [(2, 4, 6), (4, 4, 4)],
                         ids=["grouped", "depthwise"])
def test_grouped_and_depthwise_grads(mode, groups, c, n, rng):
    x, w = _data(rng, c=c, n=n, groups=groups)
    _assert_matches_lax(mode, 2, (1, 1), groups, x, w)
    _assert_matches_lax(mode, 1, ((1, 0), (0, 1)), groups, x, w)


@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_conv1d_wrappers_match_lax(mode, rng):
    x = jnp.asarray(rng.randn(2, 6, 16), jnp.float32)
    w = jnp.asarray(rng.randn(5, 6, 4) * 0.5, jnp.float32)

    for fn in (lambda m: conv1d(x, w, 2, 1, m),
               lambda m: conv1d_causal(x, w, m)):
        np.testing.assert_allclose(fn(mode), fn("lax"),
                                   rtol=1e-4, atol=1e-4, err_msg=mode)

    def loss(m):
        return lambda x_: jnp.sum(jnp.sin(conv1d_causal(x_, w, m)))
    np.testing.assert_allclose(jax.grad(loss(mode))(x),
                               jax.grad(loss("lax"))(x),
                               rtol=2e-3, atol=2e-3, err_msg=mode)


@pytest.mark.parametrize("mode", ENGINE_MODES)
def test_depthwise_causal_conv1d_grads(mode, rng):
    x = jnp.asarray(rng.randn(2, 12, 6), jnp.float32)
    w = jnp.asarray(rng.randn(4, 6) * 0.5, jnp.float32)

    def loss(m):
        return lambda x_, w_: jnp.sum(
            jnp.tanh(depthwise_causal_conv1d(x_, w_, m)))
    want = jax.grad(loss("lax"), argnums=(0, 1))(x, w)
    got = jax.grad(loss(mode), argnums=(0, 1))(x, w)
    for a, b, name in zip(want, got, ("dx", "dw")):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3,
                                   err_msg=f"{mode} {name}")


@pytest.mark.parametrize("mode", ["bp_im2col", "bp_phase", "pallas"])
def test_jit_and_vmap_compose(mode, rng):
    """jit(grad) and vmap(conv2d) both work through the custom_vjp."""
    x, w = _data(rng)
    spec = ConvSpec.make(stride=2, padding=1)
    f = jax.jit(lambda x_, w_: jax.grad(
        lambda a, b: conv2d(a, b, spec, mode).sum(),
        argnums=(0, 1))(x_, w_))
    want = jax.grad(lambda a, b: conv2d(a, b, spec, "lax").sum(),
                    argnums=(0, 1))(x, w)
    got = f(x, w)
    for a, b in zip(want, got):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3, err_msg=mode)

    xs = jnp.stack([x, x + 1])
    vm = jax.vmap(lambda xx: conv2d(xx, w, spec, mode))(xs)
    ref = jax.vmap(lambda xx: conv2d(xx, w, spec, "lax"))(xs)
    np.testing.assert_allclose(vm, ref, rtol=1e-4, atol=1e-4, err_msg=mode)


def test_tile_plan_cache_memoizes(rng):
    """Repeated layer shapes must not re-run VMEM budgeting at trace time."""
    ops.clear_tile_plan_cache()
    x, w = _data(rng)
    spec = ConvSpec.make(stride=2, padding=1)
    for _ in range(3):
        # fresh jit each time: retrace hits the plan cache, not the planner
        jax.jit(lambda a, b: conv2d(a, b, spec, "pallas"))(x, w)
        jax.jit(lambda a, b: jax.grad(
            lambda p, q: conv2d(p, q, spec, "pallas").sum(),
            argnums=(0, 1))(a, b))(x, w)
    info = ops.tile_plan_cache_info()
    for name in ("forward_plan", "input_grad_plan", "weight_grad_plan"):
        assert info[name].misses == 1, (name, info[name])
        assert info[name].hits >= 1, (name, info[name])


def test_policy_knob_flows_through_train_step():
    """make_train_step(conv_policy=...) overrides cfg.conv_policy end to
    end."""
    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.optim import adamw
    from repro.train import train_step as TS
    cfg = get_smoke_config("mamba2_370m")      # has depthwise temporal convs
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw.init_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    losses = {}
    for policy in ("lax", "bp_phase"):
        step = jax.jit(TS.make_train_step(
            cfg, adamw.AdamWConfig(peak_lr=1e-3), total_steps=10, warmup=1,
            conv_policy=policy))
        _, _, metrics = step(params, opt, batch, jnp.int32(0))
        losses[policy] = float(metrics["loss"])
    assert np.isfinite(list(losses.values())).all()
    np.testing.assert_allclose(losses["lax"], losses["bp_phase"],
                               rtol=1e-4, atol=1e-5)


def test_unknown_engine_raises(rng):
    x, w = _data(rng)
    with pytest.raises(ValueError, match="unknown conv engine"):
        conv2d(x, w, ConvSpec.make(), "nope")


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(
    hi=st.integers(4, 12), k=st.integers(1, 4), s=st.integers(1, 3),
    p_lo=st.integers(0, 2), p_hi=st.integers(0, 2),
    seed=st.integers(0, 2**16),
)
def test_property_custom_vjp_matches_lax(hi, k, s, p_lo, p_hi, seed):
    """Property: ANY valid geometry (incl. asymmetric pads), every engine's
    custom_vjp gradient == lax autodiff."""
    if p_lo > k - 1 or p_hi > k - 1 or hi + p_lo + p_hi < k:
        return
    pad = ((p_lo, p_hi), (p_hi, p_lo))
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(2, 2, hi, hi), jnp.float32)
    w = jnp.asarray(r.randn(3, 2, k, k) * 0.5, jnp.float32)
    ho = (hi + p_lo + p_hi - k) // s + 1
    if ho < 1 or k - 1 - p_hi + (hi + p_lo + p_hi - k - (ho - 1) * s) < 0:
        return
    for mode in ENGINE_MODES:
        _assert_matches_lax(mode, s, pad, 1, x, w, rtol=5e-3, atol=5e-3)
