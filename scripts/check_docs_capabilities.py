#!/usr/bin/env python
"""Docs-vs-code gate: the capability matrix in ``docs/ENGINES.md`` must
agree with the conv engines' declared capability flags.

The matrix is the markdown table whose header row is exactly

    | engine | asym_stride | dilation | transpose | paper_geometry |

Each built-in engine must have a row, and each cell must match the
registry (``repro.core.conv.ENGINES``):

    asym_stride     -> "yes" / "no"    from Engine.asym_stride
    dilation        -> "native" / "materialize"  from Engine.native_dilation
    transpose       -> "native" / "materialize"  from Engine.native_transpose
    paper_geometry  -> "yes" / "no"    from Engine.paper_geometry

Run from the repo root (CI docs lane + tier-1 test):

    PYTHONPATH=src python scripts/check_docs_capabilities.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

HEADER = ("engine", "asym_stride", "dilation", "transpose",
          "paper_geometry")


def _cells(line: str) -> list[str]:
    return [c.strip().strip("`") for c in line.strip().strip("|").split("|")]


def parse_matrix(text: str) -> dict[str, tuple[str, ...]]:
    """engine name -> (asym_stride, dilation, transpose, paper_geometry)."""
    lines = text.splitlines()
    rows: dict[str, tuple[str, ...]] = {}
    for i, line in enumerate(lines):
        if tuple(_cells(line)) != HEADER:
            continue
        for row in lines[i + 2:]:            # skip the |---| separator
            if not row.strip().startswith("|"):
                break
            cells = _cells(row)
            if len(cells) != len(HEADER) or set(cells[1]) <= {"-"}:
                continue
            rows[cells[0]] = tuple(cells[1:])
        return rows
    raise SystemExit(
        "docs/ENGINES.md: capability-matrix header row "
        f"{' | '.join(HEADER)!r} not found")


def expected() -> dict[str, tuple[str, ...]]:
    from repro.core.conv import ENGINES
    return {
        name: ("yes" if e.asym_stride else "no",
               "native" if e.native_dilation else "materialize",
               "native" if e.native_transpose else "materialize",
               "yes" if e.paper_geometry else "no")
        for name, e in ENGINES.items()
    }


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root / "src"))
    doc_path = root / "docs" / "ENGINES.md"
    documented = parse_matrix(doc_path.read_text(encoding="utf-8"))
    want = expected()
    problems = []
    for name, flags in want.items():
        if not re.fullmatch(r"[a-z0-9_]+", name):
            continue                        # test-registered oddball names
        if name not in documented:
            problems.append(f"engine {name!r} missing from the matrix")
        elif documented[name] != flags:
            problems.append(
                f"engine {name!r}: documented {documented[name]} but the "
                f"registry declares {flags}")
    for name in documented:
        if name not in want:
            problems.append(
                f"matrix documents unknown engine {name!r} "
                "(removed or renamed?)")
    if problems:
        print(f"{doc_path.relative_to(root)} capability matrix disagrees "
              "with repro.core.conv.ENGINES:", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        return 1
    print(f"ok: {doc_path.relative_to(root)} matrix matches "
          f"{len(documented)} registered engines")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
