#!/usr/bin/env python
"""Docs-vs-code gate: the event taxonomy in ``docs/OBSERVABILITY.md``
must agree with the bus registry (``repro.obs.events.KINDS``).

The taxonomy is the markdown table whose header row is exactly

    | kind | emitted by | meaning |

Every registered kind must have a row, every row must name a registered
kind, and the "emitted by" cell must match the registry's source string
verbatim (the free-form "meaning" column is not machine-checked).

Run from the repo root (CI docs lane + tier-1 test):

    PYTHONPATH=src python scripts/check_obs_events.py [root]
"""

from __future__ import annotations

import pathlib
import sys

HEADER = ("kind", "emitted by", "meaning")


def _cells(line: str) -> list[str]:
    return [c.strip().strip("`") for c in line.strip().strip("|").split("|")]


def parse_taxonomy(text: str) -> dict[str, str]:
    """kind -> "emitted by" cell, from the first table with HEADER."""
    lines = text.splitlines()
    rows: dict[str, str] = {}
    for i, line in enumerate(lines):
        if tuple(_cells(line)) != HEADER:
            continue
        for row in lines[i + 2:]:            # skip the |---| separator
            if not row.strip().startswith("|"):
                break
            cells = _cells(row)
            if len(cells) != len(HEADER) or set(cells[0]) <= {"-"}:
                continue
            rows[cells[0]] = cells[1]
        return rows
    raise SystemExit(
        "docs/OBSERVABILITY.md: event-taxonomy header row "
        f"{' | '.join(HEADER)!r} not found")


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root / "src"))
    from repro.obs.events import KINDS
    doc_path = root / "docs" / "OBSERVABILITY.md"
    documented = parse_taxonomy(doc_path.read_text(encoding="utf-8"))
    problems = []
    for kind, (source, _descr) in KINDS.items():
        if kind not in documented:
            problems.append(f"kind {kind!r} missing from the taxonomy")
        elif documented[kind] != source:
            problems.append(
                f"kind {kind!r}: documented emitter {documented[kind]!r} "
                f"but the registry declares {source!r}")
    for kind in documented:
        if kind not in KINDS:
            problems.append(
                f"taxonomy documents unregistered kind {kind!r} "
                "(removed or renamed?)")
    if problems:
        print(f"{doc_path.relative_to(root)} event taxonomy disagrees "
              "with repro.obs.events.KINDS:", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        return 1
    print(f"ok: {doc_path.relative_to(root)} taxonomy matches "
          f"{len(documented)} registered event kinds")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
