#!/usr/bin/env python
"""Grep-lint: no internal call site may pass a raw conv ``mode=`` string.

The structured surface is ``conv2d(x, w, ConvSpec, policy=...)``;
``mode="bp_phase"``-style strings are the deprecated shim and live ONLY in
``src/repro/core/conv.py`` (the shim itself) and the tests that cover it.
This script fails CI when a raw mode string (or a ``mode=cfg.conv_mode``
plumbing) sneaks back into src/, examples/, benchmarks/ or scripts/.

    python scripts/check_no_raw_mode.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

ENGINE = r"(?:lax|traditional|bp_im2col|bp_phase|pallas|auto)"
PATTERNS = [
    # mode="bp_phase" / mode='pallas' -- the deprecated stringly kwarg
    re.compile(rf"""\bmode\s*=\s*["']{ENGINE}["']"""),
    # mode=cfg.conv_mode / mode=args.conv_mode -- deprecated plumbing
    re.compile(r"\bmode\s*=\s*(?:cfg|args|self)\.conv_mode\b"),
]

SCAN_DIRS = ("src", "examples", "benchmarks", "scripts")

# The shim itself (and this linter) are the only places the deprecated
# spelling may appear.
ALLOWED = {pathlib.PurePosixPath("src/repro/core/conv.py"),
           pathlib.PurePosixPath("scripts/check_no_raw_mode.py")}


def scan(root: pathlib.Path) -> list[str]:
    hits = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = pathlib.PurePosixPath(path.relative_to(root).as_posix())
            if rel in ALLOWED:
                continue
            for lineno, line in enumerate(
                    path.read_text(encoding="utf-8").splitlines(), 1):
                for pat in PATTERNS:
                    if pat.search(line):
                        hits.append(f"{rel}:{lineno}: {line.strip()}")
    return hits


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    hits = scan(root)
    if hits:
        print("raw conv mode= strings outside the compat shim "
              "(use ConvSpec/EnginePolicy: policy=...):", file=sys.stderr)
        for h in hits:
            print("  " + h, file=sys.stderr)
        return 1
    print(f"ok: no raw conv mode= strings outside the shim "
          f"({', '.join(SCAN_DIRS)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
