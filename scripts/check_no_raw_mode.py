#!/usr/bin/env python
"""Grep-lint: deprecated spellings may not sneak back into the tree.

Two rule families, each with its own allow-list:

* raw conv ``mode=`` strings -- the structured surface is
  ``conv2d(x, w, ConvSpec, policy=...)``; ``mode="bp_phase"``-style
  strings are the deprecated shim and live ONLY in
  ``src/repro/core/conv.py`` (the shim itself) and the tests covering it.
* raw ``os.environ`` reads of the ``REPRO_*`` / ``BPIM2COL_*`` knobs --
  the knobs live on ``repro.config`` (``src/repro/core/config.py`` is the
  single module allowed to touch their env vars).  Writing them into a
  subprocess environment dict is fine; READING them anywhere else is not.

This script fails CI on any hit in src/, examples/, benchmarks/ or
scripts/.

    python scripts/check_no_raw_mode.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

ENGINE = r"(?:lax|traditional|bp_im2col|bp_phase|pallas|auto)"

_P = pathlib.PurePosixPath

#: (description, [compiled patterns], {allowed files})
RULES = [
    ("raw conv mode= strings outside the compat shim "
     "(use ConvSpec/EnginePolicy: policy=...)",
     [  # mode="bp_phase" / mode='pallas' -- the deprecated stringly kwarg
        re.compile(rf"""\bmode\s*=\s*["']{ENGINE}["']"""),
        # mode=cfg.conv_mode / mode=args.conv_mode -- deprecated plumbing
        re.compile(r"\bmode\s*=\s*(?:cfg|args|self)\.conv_mode\b")],
     {_P("src/repro/core/conv.py"),
      _P("scripts/check_no_raw_mode.py")}),
    ("raw os.environ reads of REPRO_*/BPIM2COL_* knobs outside "
     "repro/core/config.py (use repro.config)",
     [  # os.environ.get("REPRO_X") / os.environ["BPIM2COL_X"], any alias
        # of the os module (import os as _os).
        re.compile(r"""environ\s*\.\s*get\s*\(\s*["'](?:REPRO_|BPIM2COL_)"""),
        re.compile(r"""environ\s*\[\s*["'](?:REPRO_|BPIM2COL_)""")],
     {_P("src/repro/core/config.py"),
      _P("scripts/check_no_raw_mode.py")}),
]

SCAN_DIRS = ("src", "examples", "benchmarks", "scripts")


def scan(root: pathlib.Path) -> dict[str, list[str]]:
    hits: dict[str, list[str]] = {}
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = _P(path.relative_to(root).as_posix())
            lines = path.read_text(encoding="utf-8").splitlines()
            for desc, patterns, allowed in RULES:
                if rel in allowed:
                    continue
                for lineno, line in enumerate(lines, 1):
                    if any(p.search(line) for p in patterns):
                        hits.setdefault(desc, []).append(
                            f"{rel}:{lineno}: {line.strip()}")
    return hits


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    hits = scan(root)
    if hits:
        for desc, lines in hits.items():
            print(f"{desc}:", file=sys.stderr)
            for h in lines:
                print("  " + h, file=sys.stderr)
        return 1
    print(f"ok: no raw conv mode= strings or raw REPRO_*/BPIM2COL_* env "
          f"reads outside their shims ({', '.join(SCAN_DIRS)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
