#!/usr/bin/env python
"""Drive the dry-run sweep: one subprocess per (arch x shape) cell.

Per-cell isolation means one pathological compile can't kill the sweep; a
cell that exceeds --timeout with unrolled scan is retried in scan mode
(compile/memory/collectives still recorded; flops marked undercounted).

Usage:
  python scripts/dryrun_sweep.py [--multi-pod] [--unroll 9999] [--timeout 1800]
"""

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import all_arch_ids, get_config          # noqa: E402
from repro.configs.base import applicable_shapes            # noqa: E402

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def cell_cost(arch, shape):
    cfg = get_config(arch)
    return cfg.n_layers * (2 if cfg.n_experts else 1)


def run(arch, shape, multi_pod, unroll, timeout, conv=None):
    env = dict(os.environ)
    env["REPRO_SCAN_UNROLL"] = str(unroll)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    if conv:
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--conv", conv]
    else:
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    t0 = time.time()
    try:
        r = subprocess.run(cmd, env=env, timeout=timeout,
                           capture_output=True, text=True, cwd=ROOT)
        ok = r.returncode == 0
        msg = (r.stdout + r.stderr).strip().splitlines()
        return ok, time.time() - t0, (msg[-3:] if msg else [])
    except subprocess.TimeoutExpired:
        return None, time.time() - t0, ["TIMEOUT"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", type=int, default=9999)
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--only-arch", default=None)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--conv-only", action="store_true",
                    help="run only the mesh-parallel conv cells "
                         "(tp / dp_only / spatial autoencoder compiles "
                         "with the sharded-path gate)")
    args = ap.parse_args()

    if args.conv_only:
        failures = []
        for pol in ("tp", "dp_only", "spatial"):
            print(f"=== conv cell {pol} (multi_pod={args.multi_pod})",
                  flush=True)
            ok, dt, tail = run(None, None, args.multi_pod, args.unroll,
                               args.timeout, conv=pol)
            status = "OK" if ok else "FAIL"
            print(f"    {status} {dt:.0f}s :: " + " | ".join(tail),
                  flush=True)
            if not ok:
                failures.append(pol)
        if failures:
            raise SystemExit(f"conv dry-run failures: {failures}")
        print("=== conv sweep done: 3/3 OK", flush=True)
        return

    cells = []
    for a in all_arch_ids():
        if args.only_arch and a != args.only_arch:
            continue
        for s in applicable_shapes(get_config(a)):
            cells.append((a, s))
    cells.sort(key=lambda c: cell_cost(*c))

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    summary = []
    for i, (a, s) in enumerate(cells):
        report = os.path.join(ROOT, "reports", "dryrun",
                              f"{a}__{s}__{mesh_name}.json")
        if args.skip_existing and os.path.exists(report):
            print(f"=== [{i+1}/{len(cells)}] {a} {s} SKIP (exists)",
                  flush=True)
            continue
        print(f"=== [{i+1}/{len(cells)}] {a} {s} "
              f"(multi_pod={args.multi_pod}, unroll={args.unroll})",
              flush=True)
        ok, dt, tail = run(a, s, args.multi_pod, args.unroll, args.timeout)
        if ok is None and args.unroll > 1:
            print(f"    timeout after {dt:.0f}s; retry scan-mode", flush=True)
            ok, dt, tail = run(a, s, args.multi_pod, 1, args.timeout)
            tail.append("flops-undercounted(scan-mode)")
        status = "OK" if ok else "FAIL"
        print(f"    {status} {dt:.0f}s :: " + " | ".join(tail), flush=True)
        summary.append({"arch": a, "shape": s, "ok": bool(ok),
                        "seconds": round(dt, 1), "tail": tail})
        mode = "multipod" if args.multi_pod else "singlepod"
        with open(os.path.join(ROOT, "reports", f"sweep_{mode}.json"),
                  "w") as f:
            json.dump(summary, f, indent=2)
    n_ok = sum(1 for s in summary if s["ok"])
    print(f"=== sweep done: {n_ok}/{len(summary)} OK", flush=True)


if __name__ == "__main__":
    main()
