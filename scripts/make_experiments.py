#!/usr/bin/env python
"""Assemble the generated tables for EXPERIMENTS.md from reports/.

Prints markdown for: §Dry-run (per-cell compile/memory summary for both
meshes) and §Roofline (three-term table).  The hand-written analysis and
§Perf iteration log live in EXPERIMENTS.md itself; this script's output is
pasted into the marked sections at finalization.
"""

import glob
import json
import os
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, ROOT)
sys.path.insert(0, os.path.join(ROOT, "src"))

from benchmarks import roofline                     # noqa: E402


def dryrun_table(mesh_filter=None, baseline_only=True):
    rows = []
    for path in sorted(glob.glob(os.path.join(ROOT, "reports", "dryrun",
                                              "*.json"))):
        with open(path) as f:
            c = json.load(f)
        if mesh_filter and c["mesh"] != mesh_filter:
            continue
        base = os.path.basename(path)[:-5]
        if baseline_only and (c.get("policy", "tp") != "tp"
                              or c.get("window_skip", False)
                              or base.count("__") > 2):
            continue
        mem = c["memory"]
        per_dev_temp = (mem["temp_size_in_bytes"] or 0) / c["n_devices"]
        args_b = (mem["argument_size_in_bytes"] or 0)
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "mesh": c["mesh"],
            "compile_s": c["compile_s"],
            "flops": c["flops"],
            "coll_total": c["collective_bytes"]["total"],
            "temp_gb_per_dev": per_dev_temp / 2**30,
            "args_gb_total": args_b / 2**30,
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | mesh | compile (s) | HLO FLOPs (global) | "
           "collective B/dev | temp GiB/dev | args GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                   f"| {r['compile_s']} | {r['flops']:.3e} "
                   f"| {r['coll_total']:.3e} | {r['temp_gb_per_dev']:.2f} "
                   f"| {r['args_gb_total']:.1f} |")
    return "\n".join(out)


if __name__ == "__main__":
    print("## Generated: single-pod dry-run (16x16)\n")
    print(dryrun_table("16x16"))
    print("\n## Generated: multi-pod dry-run (2x16x16)\n")
    print(dryrun_table("2x16x16"))
    print("\n## Generated: roofline table\n")
    print(roofline.markdown())
