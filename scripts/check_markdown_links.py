#!/usr/bin/env python
"""Markdown link check for ``docs/`` + ``README.md``.

Every relative link target (``[text](path)`` and ``[text](path#anchor)``)
must exist on disk, and every intra-document ``#anchor`` must match a
heading in the target file (GitHub slug rules, simplified).  External
``http(s)://`` links are not fetched -- this is an offline structural
check, run by the CI docs lane and the tier-1 suite.

    python scripts/check_markdown_links.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _slug(heading: str) -> str:
    """GitHub-style anchor slug (simplified: enough for our headings).
    Every space becomes a hyphen and punctuation is dropped WITHOUT
    collapsing, so "A → B" slugs to "a--b" exactly like GitHub."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\s-]", "", text)
    return text.replace(" ", "-")


def _anchors(path: pathlib.Path) -> set[str]:
    return {_slug(h) for h in HEADING.findall(
        path.read_text(encoding="utf-8"))}


def check(root: pathlib.Path) -> list[str]:
    files = sorted((root / "docs").glob("**/*.md")) if \
        (root / "docs").is_dir() else []
    if (root / "README.md").is_file():
        files.append(root / "README.md")
    problems = []
    for md in files:
        rel = md.relative_to(root)
        for target in LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, anchor = target.partition("#")
            dest = md if not path_part else \
                (md.parent / path_part).resolve()
            if not dest.exists():
                problems.append(f"{rel}: broken link -> {target}")
                continue
            if anchor and dest.suffix == ".md" and \
                    _slug(anchor) not in _anchors(dest):
                problems.append(f"{rel}: missing anchor -> {target}")
    if not files:
        problems.append("no markdown files found under docs/ or README.md")
    return problems


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    problems = check(root)
    if problems:
        print("markdown link check failed:", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        return 1
    print("ok: all relative markdown links in docs/ + README.md resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
