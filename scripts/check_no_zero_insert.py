#!/usr/bin/env python
"""Grep-lint: no hand-rolled zero-insertion upsampling outside ``core/``.

The whole point of the transposed-conv subsystem (``ConvTransposeSpec`` +
``conv2d_transpose``) is that lhs dilation is resolved at PLAN time -- the
zero-spaced tensor is never built.  A call site that zero-inserts by hand
(a ``jnp.zeros`` buffer scattered into with a strided ``.at[::s].set`` --
the classic upsampling idiom) silently re-materializes exactly the
zero-space the paper eliminates, off the engines' books.

This script fails CI when the strided-scatter idiom (or an explicit
``lax.pad`` interior dilation) sneaks into src/, examples/, benchmarks/ or
scripts/ outside ``src/repro/core`` -- the engines' own implementation
(``zero_insert``, the phase decomposition's per-phase writeback, the
materialization oracle) is the ONLY place it may live.  New upsampling
call sites go through ``repro.core.conv2d_transpose``.

    python scripts/check_no_zero_insert.py [root]
"""

from __future__ import annotations

import pathlib
import re
import sys

PATTERNS = [
    # .at[..., ::s_h, ::s_w].set(x) -- strided scatter into a zeros buffer
    re.compile(r"\.at\[[^\]]*::[^\]]*\]\s*\.set\("),
    # lax.pad(..., (lo, hi, interior>0)) spelled with an explicit interior
    # dilation variable is hard to grep exactly; catch the canonical
    # zero-insertion helper being re-implemented under a local name.
    re.compile(r"def\s+zero_insert\w*\("),
]

SCAN_DIRS = ("src", "examples", "benchmarks", "scripts")

# The engines' own implementation of zero-space (the explicit baseline,
# the phase writeback, the materialization oracle) and this linter.
ALLOWED_PREFIXES = ("src/repro/core/",)
ALLOWED = {pathlib.PurePosixPath("scripts/check_no_zero_insert.py")}


def scan(root: pathlib.Path) -> list[str]:
    hits = []
    for d in SCAN_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = pathlib.PurePosixPath(path.relative_to(root).as_posix())
            if rel in ALLOWED or str(rel).startswith(ALLOWED_PREFIXES):
                continue
            for lineno, line in enumerate(
                    path.read_text(encoding="utf-8").splitlines(), 1):
                for pat in PATTERNS:
                    if pat.search(line):
                        hits.append(f"{rel}:{lineno}: {line.strip()}")
    return hits


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    hits = scan(root)
    if hits:
        print("hand-rolled zero-insertion upsampling outside core/ "
              "(use repro.core.conv2d_transpose):", file=sys.stderr)
        for h in hits:
            print("  " + h, file=sys.stderr)
        return 1
    print(f"ok: no hand-rolled zero-insertion upsampling outside core/ "
          f"({', '.join(SCAN_DIRS)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
