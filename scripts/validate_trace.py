#!/usr/bin/env python
"""Validate a ``repro.obs.trace`` export: Perfetto-loadable trace_event
JSON with balanced, properly nested spans, plus (optionally) a metrics
JSONL stream.

Checks, all hard failures:

  * the file is a JSON object with a ``traceEvents`` list;
  * every event carries ``name`` / ``ph`` / ``pid`` / ``tid`` / ``ts``
    with ``ph`` one of B/E (the only phases the tracer emits);
  * per ``(pid, tid)`` lane, B/E events BALANCE and NEST: every E closes
    the most recent open B of the same name, and nothing stays open;
  * timestamps never run backwards within a lane;
  * every ``conv:*`` dispatch span carries the paper-facing annotations
    ``skip_ratio`` and ``bytes_moved`` in its ``args``;
  * ``--require-span SUBSTR`` (repeatable): at least one B event whose
    name contains SUBSTR exists;
  * ``--metrics FILE``: every line parses as JSON with ``ts`` + ``kind``;
  * ``--require-metrics-kind KIND`` (repeatable): at least one metrics
    line of that kind exists.

Run from the repo root (CI obs lane):

    python scripts/validate_trace.py out.json \
        --require-span conv: --metrics m.jsonl \
        --require-metrics-kind train_step
"""

from __future__ import annotations

import argparse
import json
import sys

REQUIRED_KEYS = ("name", "ph", "pid", "tid", "ts")


def validate_trace(doc: dict) -> tuple[list[str], dict]:
    """Returns (problems, stats).  ``doc`` is the parsed trace file."""
    problems: list[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return (["top-level 'traceEvents' missing or not a list"], {})
    stacks: dict[tuple, list[dict]] = {}
    last_ts: dict[tuple, float] = {}
    b_names: list[str] = []
    for i, e in enumerate(events):
        missing = [k for k in REQUIRED_KEYS if k not in e]
        if missing:
            problems.append(f"event #{i} missing keys {missing}: {e}")
            continue
        if e["ph"] not in ("B", "E"):
            problems.append(f"event #{i} has phase {e['ph']!r} "
                            "(tracer only emits B/E)")
            continue
        lane = (e["pid"], e["tid"])
        if e["ts"] < last_ts.get(lane, float("-inf")):
            problems.append(
                f"event #{i} ({e['name']!r}): ts runs backwards in lane "
                f"{lane}")
        last_ts[lane] = e["ts"]
        stack = stacks.setdefault(lane, [])
        if e["ph"] == "B":
            stack.append(e)
            b_names.append(e["name"])
            if e["name"].startswith("conv:"):
                args = e.get("args", {})
                for key in ("skip_ratio", "bytes_moved"):
                    if key not in args:
                        problems.append(
                            f"conv span {e['name']!r} (event #{i}) lacks "
                            f"the {key!r} annotation: args={args}")
        else:
            if not stack:
                problems.append(
                    f"event #{i}: E {e['name']!r} with no open span in "
                    f"lane {lane}")
                continue
            top = stack.pop()
            if top["name"] != e["name"]:
                problems.append(
                    f"event #{i}: E {e['name']!r} closes B "
                    f"{top['name']!r} (spans must nest)")
    for lane, stack in stacks.items():
        if stack:
            problems.append(
                f"lane {lane}: {len(stack)} span(s) left open: "
                f"{[s['name'] for s in stack]}")
    return problems, {"events": len(events), "b_names": b_names}


def validate_metrics(path: str) -> tuple[list[str], list[dict]]:
    problems: list[str] = []
    lines: list[dict] = []
    with open(path) as f:
        for i, raw in enumerate(f):
            raw = raw.strip()
            if not raw:
                continue
            try:
                rec = json.loads(raw)
            except json.JSONDecodeError as e:
                problems.append(f"{path}:{i + 1}: not JSON ({e})")
                continue
            for key in ("ts", "kind"):
                if key not in rec:
                    problems.append(f"{path}:{i + 1}: missing {key!r}")
            lines.append(rec)
    if not lines:
        problems.append(f"{path}: no metrics lines")
    return problems, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="trace_event JSON written by "
                                  "repro.obs.trace.export")
    ap.add_argument("--require-span", action="append", default=[],
                    metavar="SUBSTR",
                    help="fail unless a B span whose name contains SUBSTR "
                         "exists (repeatable)")
    ap.add_argument("--metrics", metavar="FILE", default=None,
                    help="also validate this metrics JSONL stream")
    ap.add_argument("--require-metrics-kind", action="append", default=[],
                    metavar="KIND",
                    help="fail unless a metrics line of this kind exists "
                         "(repeatable)")
    args = ap.parse_args(argv)

    with open(args.trace) as f:
        doc = json.load(f)
    problems, stats = validate_trace(doc)
    for sub in args.require_span:
        if not any(sub in n for n in stats.get("b_names", [])):
            problems.append(
                f"{args.trace}: no span matching {sub!r} "
                f"(spans: {sorted(set(stats.get('b_names', [])))})")
    n_metrics = 0
    if args.metrics:
        mproblems, lines = validate_metrics(args.metrics)
        problems.extend(mproblems)
        n_metrics = len(lines)
        kinds = {rec.get("kind") for rec in lines}
        for kind in args.require_metrics_kind:
            if kind not in kinds:
                problems.append(
                    f"{args.metrics}: no line of kind {kind!r} "
                    f"(kinds: {sorted(k for k in kinds if k)})")
    if problems:
        print(f"INVALID: {args.trace}", file=sys.stderr)
        for p in problems:
            print("  " + p, file=sys.stderr)
        return 1
    msg = f"ok: {args.trace}: {stats['events']} events, " \
          f"{len(stats['b_names'])} spans, all balanced and nested"
    if args.metrics:
        msg += f"; {args.metrics}: {n_metrics} metrics lines"
    print(msg)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
